//! Tensor-list optimizer interface for deep-learning training.
//!
//! A model is a list of matrix-shaped parameters (vectors are n×1). The
//! coordinator's training loop drives these optimizers with gradients
//! produced by the AOT-compiled L2 artifacts; the optimizers themselves —
//! the paper's contribution — run entirely in Rust.

use crate::coordinator::wire::BlockStateMsg;
use crate::tensor::Matrix;

/// Optimizer over a list of matrix parameters.
pub trait Optimizer {
    /// Display name used in experiment tables.
    fn name(&self) -> String;

    /// One training step: update `params[i]` using `grads[i]`.
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]);

    /// Fallible step. In-process optimizers never fail and inherit this
    /// default; optimizers backed by fallible executors (the
    /// cross-process shard engine) override it so worker/transport
    /// failures reach the training loop as errors naming the shard
    /// instead of panics.
    fn try_step(&mut self, params: &mut [Matrix], grads: &[Matrix]) -> anyhow::Result<()> {
        self.step(params, grads);
        Ok(())
    }

    /// Total heap bytes of optimizer state.
    fn mem_bytes(&self) -> usize;

    /// Bytes used for *second-moment* (covariance) state only — the
    /// quantity Fig. 1 compares across methods.
    fn second_moment_bytes(&self) -> usize {
        self.mem_bytes()
    }

    /// Update the learning rate (for schedules driven by the trainer).
    fn set_lr(&mut self, lr: f64);

    /// Steps taken so far.
    fn steps(&self) -> usize;

    /// Typed snapshot of the optimizer state as checkpoint/wire
    /// [`BlockStateMsg`] records (one per block, in block order, FD
    /// sketches factored). `Ok(None)` means this optimizer has no
    /// typed-state surface — its checkpoints carry parameters only.
    fn state_payloads(&mut self) -> anyhow::Result<Option<Vec<BlockStateMsg>>> {
        Ok(None)
    }

    /// Restore a [`Optimizer::state_payloads`] snapshot taken at
    /// `step`. Entries are validated against the optimizer's own block
    /// table before anything is applied; on success the optimizer steps
    /// bitwise-identically to the snapshotted one.
    fn restore_payloads(&mut self, _step: usize, _entries: Vec<BlockStateMsg>) -> anyhow::Result<()> {
        anyhow::bail!("optimizer {} does not support typed state restore", self.name())
    }
}

/// Learning-rate schedule used across the paper's experiments (App. C):
/// linear warmup to `peak` over `warmup` steps, then cosine decay to 0 at
/// `total` steps.
#[derive(Clone, Copy, Debug)]
pub struct WarmupCosine {
    pub peak: f64,
    pub warmup: usize,
    pub total: usize,
}

impl WarmupCosine {
    pub fn at(&self, step: usize) -> f64 {
        if self.total == 0 {
            return self.peak;
        }
        if step < self.warmup {
            return self.peak * (step as f64 + 1.0) / self.warmup.max(1) as f64;
        }
        let frac = (step - self.warmup) as f64 / (self.total - self.warmup).max(1) as f64;
        let frac = frac.min(1.0);
        0.5 * self.peak * (1.0 + (std::f64::consts::PI * frac).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_cosine_shape() {
        let s = WarmupCosine { peak: 1.0, warmup: 10, total: 110 };
        assert!(s.at(0) > 0.0 && s.at(0) <= 0.2);
        assert!((s.at(9) - 1.0).abs() < 1e-9);
        assert!(s.at(60) < 1.0 && s.at(60) > 0.0);
        assert!(s.at(109) < 0.01);
        // Monotone up then down.
        assert!(s.at(5) > s.at(2));
        assert!(s.at(100) < s.at(50));
    }
}
