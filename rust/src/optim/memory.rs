//! Asymptotic memory accounting for Fig. 1.
//!
//! Bytes used to represent the *gradient covariance* (second moments) of
//! a single m×n matrix parameter under each adaptive method, with `r` the
//! GGT history length and `k` the FD/sketch rank. Figures/tables from E2
//! are generated from these formulas plus live measurements of the actual
//! optimizer structs (see `examples/memory_budget.rs`), which must agree.

/// Adaptive-regularization methods compared in Fig. 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full-matrix AdaGrad: (mn)² covariance.
    AdaGradFull,
    /// GGT (Agarwal et al. [6]): mn × r gradient history.
    Ggt,
    /// Ada-FD / RadaGrad: rank-r sketch of the full covariance, mn × r.
    AdaFdFull,
    /// Shampoo: m² + n² Kronecker factors.
    Shampoo,
    /// Sketchy (this paper): (m+n) × k factored sketches.
    Sketchy,
    /// Adam / diagonal AdaGrad: mn diagonal.
    Adam,
    /// AdaFactor: m + n factored diagonal.
    AdaFactor,
    /// SM3: m + n cover-set accumulators.
    Sm3,
    /// Online gradient descent: no second moments.
    Ogd,
}

impl Method {
    pub const ALL: [Method; 9] = [
        Method::AdaGradFull,
        Method::Ggt,
        Method::AdaFdFull,
        Method::Shampoo,
        Method::Sketchy,
        Method::Adam,
        Method::AdaFactor,
        Method::Sm3,
        Method::Ogd,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::AdaGradFull => "AdaGrad (full)",
            Method::Ggt => "GGT",
            Method::AdaFdFull => "Ada-FD/RadaGrad",
            Method::Shampoo => "Shampoo",
            Method::Sketchy => "Sketchy",
            Method::Adam => "Adam/diag-AdaGrad",
            Method::AdaFactor => "AdaFactor",
            Method::Sm3 => "SM3",
            Method::Ogd => "OGD",
        }
    }

    /// Asymptotic formula as a string (the Fig. 1 annotations).
    pub fn formula(&self) -> &'static str {
        match self {
            Method::AdaGradFull => "(mn)^2",
            Method::Ggt => "mnr",
            Method::AdaFdFull => "mnr",
            Method::Shampoo => "m^2 + n^2",
            Method::Sketchy => "(m+n)k",
            Method::Adam => "mn",
            Method::AdaFactor => "m + n",
            Method::Sm3 => "m + n",
            Method::Ogd => "0",
        }
    }

    /// Number of f64 entries used for second moments of one m×n tensor.
    pub fn second_moment_floats(&self, m: usize, n: usize, r: usize, k: usize) -> usize {
        let d = m * n;
        match self {
            Method::AdaGradFull => d * d,
            Method::Ggt => d * r,
            Method::AdaFdFull => d * r,
            Method::Shampoo => m * m + n * n,
            Method::Sketchy => (m + n) * k,
            Method::Adam => d,
            Method::AdaFactor => m + n,
            Method::Sm3 => m + n,
            Method::Ogd => 0,
        }
    }

    pub fn second_moment_bytes(&self, m: usize, n: usize, r: usize, k: usize) -> usize {
        8 * self.second_moment_floats(m, n, r, k)
    }

    /// Is the representation sub-linear in the parameter count mn?
    pub fn sublinear(&self, m: usize, n: usize, r: usize, k: usize) -> bool {
        self.second_moment_floats(m, n, r, k) < m * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_ordering_at_paper_scale() {
        // BERT-Large FFN kernel: 4096×1024, r = k = 256 (paper's values).
        let (m, n, r, k) = (4096usize, 1024, 256, 256);
        let bytes: Vec<usize> = Method::ALL
            .iter()
            .map(|meth| meth.second_moment_bytes(m, n, r, k))
            .collect();
        let by = |meth: Method| meth.second_moment_bytes(m, n, r, k);
        // The Fig. 1 ordering: AdaFactor/SM3 < Sketchy < Adam < Shampoo < GGT < AdaGrad.
        assert!(by(Method::AdaFactor) < by(Method::Sketchy));
        assert!(by(Method::Sketchy) < by(Method::Adam));
        assert!(by(Method::Adam) < by(Method::Shampoo));
        assert!(by(Method::Shampoo) < by(Method::Ggt));
        assert!(by(Method::Ggt) < by(Method::AdaGradFull));
        assert!(bytes.iter().all(|&b| b < usize::MAX));
    }

    #[test]
    fn sketchy_is_sublinear_adam_is_not() {
        let (m, n, r, k) = (4096usize, 1024, 256, 256);
        assert!(Method::Sketchy.sublinear(m, n, r, k));
        assert!(Method::AdaFactor.sublinear(m, n, r, k));
        assert!(!Method::Adam.sublinear(m, n, r, k));
        assert!(!Method::Shampoo.sublinear(m, n, r, k));
    }

    #[test]
    fn resnet50_scale_sanity() {
        // Paper intro: 23M params ⇒ full covariance > 2 petabytes.
        // Treat the model as a single vector (m = 23e6, n = 1).
        let bytes = Method::AdaGradFull.second_moment_bytes(23_000_000, 1, 0, 0);
        // Using f64 (the paper says >2PB with f32; f64 doubles it).
        assert!(bytes as f64 > 2e15);
    }

    #[test]
    fn matches_live_optimizers() {
        use crate::optim::shampoo::{Shampoo, ShampooConfig};
        use crate::optim::s_shampoo::{SShampoo, SShampooConfig};
        use crate::optim::matrix_opt::Optimizer;
        let shapes = [(64, 32)];
        let sh = Shampoo::new(&shapes, ShampooConfig::default());
        assert_eq!(
            sh.second_moment_bytes(),
            Method::Shampoo.second_moment_bytes(64, 32, 0, 0)
        );
        let rank = 8;
        let ssh = SShampoo::new(&shapes, SShampooConfig {
            rank,
            ..Default::default()
        });
        // Live sketches also hold their ℓ eigenvalues: (m+n)·k + 2k floats.
        assert_eq!(
            ssh.second_moment_bytes(),
            Method::Sketchy.second_moment_bytes(64, 32, 0, rank) + 2 * rank * 8
        );
    }
}
