//! The optimizer family (system S4) — the paper's algorithmic content.
//!
//! **Vector world** (OCO experiments, Sec. 4 / App. A):
//! - [`SAdaGrad`] — Sketchy AdaGrad, Alg. 2 (ours)
//! - [`Ogd`], [`AdaGradDiag`] — first-order baselines
//! - [`AdaGradFull`], [`EpochAdaGrad`] — d² baselines (Tbl. 1, App. G)
//! - [`AdaFd`], [`FdSon`], [`RfdSon`] — FD-sketched related work
//!
//! **Tensor world** (DL experiments, Sec. 5):
//! - [`SShampoo`] — Sketchy Shampoo, Alg. 3 + §4.3 (ours)
//! - [`Shampoo`] — exact Kronecker preconditioner
//! - [`Adam`], [`Sgd`] — first-order baselines
//! - [`Blocked`] — Blocked-Shampoo wrapper (§3.4)
//! - [`grafting`] — layer-wise grafting (App. C)
//! - [`memory`] — Fig. 1 memory accounting
//!
//! **Engine layer** (production path):
//! - [`Preconditioner`] — the unified ingest/refresh/apply interface
//!   behind Shampoo, S-Shampoo and Adam ([`precond`])
//! - [`PrecondEngine`] — parallel blocked engine driving any unit kind
//!   with a staggered stale-refresh schedule ([`engine`])
//! - [`BlockExecutor`] — the engine's execution substrate: the
//!   in-process work queue ([`LocalExecutor`]) or cross-process shard
//!   workers ([`crate::coordinator::shard::ShardExecutor`])
//! - [`ExecutorBuilder`] — the one construction path over all of the
//!   above (local / sharded / in-proc harness / custom), threading the
//!   elastic membership knobs ([`builder`])

pub mod adam;
pub mod blocking;
pub mod builder;
pub mod engine;
pub mod fd_baselines;
pub mod first_order;
pub mod full_matrix;
pub mod ggt;
pub mod grafting;
pub mod matrix_opt;
pub mod memory;
pub mod precond;
pub mod s_adagrad;
pub mod s_shampoo;
pub mod shampoo;
pub mod vector;

pub use adam::{Adam, Sgd};
pub use blocking::{partition, Block, Blocked};
pub use builder::ExecutorBuilder;
pub use engine::{
    engine_optimizer, sharded_engine_optimizer, BlockExecutor, EngineConfig, LocalExecutor,
    PrecondEngine, RefreshAheadDone, RefreshAheadPlan, UnitKind,
};
pub use fd_baselines::{AdaFd, FdSon, RfdSon};
pub use first_order::{AdaGradDiag, Ogd};
pub use full_matrix::{AdaGradFull, EpochAdaGrad};
pub use ggt::Ggt;
pub use grafting::{Graft, GraftType};
pub use matrix_opt::{Optimizer, WarmupCosine};
pub use memory::Method as MemoryMethod;
pub use precond::{AdamUnit, BlockState, KroneckerUnit, Preconditioner, SketchUnit};
pub use s_adagrad::SAdaGrad;
pub use s_shampoo::{SShampoo, SShampooConfig};
pub use shampoo::{Shampoo, ShampooConfig};
pub use vector::VectorOptimizer;
