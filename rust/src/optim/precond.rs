//! The unified preconditioner interface behind the tensor-world optimizer
//! family.
//!
//! Every second-order method in this repository decomposes into the same
//! three per-tensor (or per-block) operations:
//!
//! 1. **ingest** — fold a gradient into the second-moment statistics
//!    (exact Kronecker factors, FD sketches, or a diagonal accumulator);
//! 2. **refresh** — recompute the expensive derived state (inverse-root
//!    eigendecompositions) from the current statistics;
//! 3. **apply** — precondition a gradient with the derived state.
//!
//! [`Preconditioner`] captures that contract. [`Shampoo`](super::Shampoo)
//! and [`SShampoo`](super::SShampoo) drive the units serially with their
//! paper-faithful cadences; the parallel block engine
//! ([`super::engine::PrecondEngine`]) drives the very same units across a
//! thread pool with a staggered stale-refresh schedule, so the eigh calls
//! of different blocks overlap instead of serializing the step (§3.4 /
//! §7 amortization).
//!
//! Splitting ingest/refresh/apply is what makes staleness a *schedule*
//! decision rather than an algorithm change: a unit is always safe to
//! apply with roots computed from older statistics, which is exactly the
//! production Shampoo trick (`precond_interval` in App. C).

use super::grafting::{transplant, Graft, GraftType};
use crate::sketch::FdSketch;
use crate::tensor::{a_at, at_a, inv_pth_root, matmul, Matrix};

/// Per-tensor/per-block preconditioner unit: statistics + derived state.
///
/// `Send` so the block engine can move units across worker threads.
pub trait Preconditioner: Send {
    /// Fold gradient `g` into the second-moment statistics.
    fn ingest(&mut self, g: &Matrix);

    /// Recompute derived state (inverse roots) from current statistics.
    /// Returns `true` only when real work ran (an eigendecomposition) —
    /// no-op refreshes (diagonal units, fully-sketched sides) return
    /// `false` so the engine's amortization accounting stays honest.
    fn refresh(&mut self) -> bool;

    /// Whether derived state exists (first apply must be preceded by a
    /// refresh for units with cached roots).
    fn ready(&self) -> bool;

    /// Preconditioned direction for gradient `g`.
    fn apply(&self, g: &Matrix) -> Matrix;

    /// Total heap bytes of unit state.
    fn mem_bytes(&self) -> usize;

    /// Bytes of second-moment (covariance) state only.
    fn second_moment_bytes(&self) -> usize;

    /// Live FD sketches backing this unit (sketched families only) —
    /// exposed for invariant checks and diagnostics.
    fn sketches(&self) -> Vec<&FdSketch> {
        vec![]
    }

    /// Serializable snapshot of the unit's mutable state — the typed
    /// payload behind wire protocol v4 and checkpoint format v2. Sketched
    /// sides export their rank-ℓ factors (O(dℓ)), never a materialized
    /// d×d covariance.
    fn state_payload(&self) -> PrecondState;

    /// Restore a [`Preconditioner::state_payload`] snapshot. The payload
    /// kind and every shape/rank must match this unit's construction
    /// (hyperparameters are construction-owned and never travel); on
    /// success the unit is bitwise identical to the snapshotted one. A
    /// failed restore may leave the unit partially updated — callers
    /// treat an `Err` as fatal for the hosting engine.
    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()>;
}

// ---------------------------------------------------------------------------
// Typed state snapshots (wire v4 / checkpoint v2 payloads).
// ---------------------------------------------------------------------------

/// Snapshot of one preconditioner unit's mutable state, in the unit's
/// natural factored form. This is the *semantic* payload type; the wire
/// and checkpoint codecs ([`crate::coordinator::wire::StatePayload`])
/// encode it without ever densifying sketched sides.
#[derive(Clone, Debug)]
pub enum PrecondState {
    /// Exact Kronecker factors and their cached inverse roots.
    Kronecker { l: Matrix, r: Matrix, l_root: Option<Matrix>, r_root: Option<Matrix> },
    /// Per-side sketched (or small-exact) factors.
    Sketch { left: SideState, right: SideState },
    /// Diagonal Adam moments + step counter.
    Diag { m: Matrix, v: Matrix, t: u64 },
}

/// One side of a [`PrecondState::Sketch`] snapshot.
#[derive(Clone, Debug)]
pub enum SideState {
    /// dim ≤ ℓ: exact factor plus cached root.
    Exact { c: Matrix, root: Option<Matrix> },
    /// dim > ℓ: the FD sketch's factored state.
    Sketch(SketchState),
}

/// Factored FD sketch state: O(dℓ) basis + ℓ eigenvalues + the RFD-style
/// escaped-mass accumulator that makes the sketch a self-contained
/// serialization unit (restore needs no replay of the stream).
#[derive(Clone, Debug)]
pub struct SketchState {
    /// Orthonormal eigenbasis, d×ℓ.
    pub basis: Matrix,
    /// Eigenvalues, descending, length ℓ.
    pub eigvals: Vec<f64>,
    /// Cumulative escaped mass ρ_{1:t}.
    pub escaped_mass: f64,
    /// Escaped mass of the most recent update.
    pub last_rho: f64,
    /// Update counter.
    pub steps: u64,
}

fn ensure_shape(what: &str, m: &Matrix, rows: usize, cols: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        m.rows() == rows && m.cols() == cols,
        "state restore: {what} shape {}x{} != expected {rows}x{cols}",
        m.rows(),
        m.cols()
    );
    Ok(())
}

fn ensure_opt_shape(
    what: &str,
    m: &Option<Matrix>,
    rows: usize,
    cols: usize,
) -> anyhow::Result<()> {
    if let Some(m) = m {
        ensure_shape(what, m, rows, cols)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Exact Kronecker factors (Shampoo).
// ---------------------------------------------------------------------------

/// Exact Shampoo unit: EMA factors `L ← β₂L + G Gᵀ`, `R ← β₂R + GᵀG` with
/// cached inverse roots `L^{-1/4}` / `R^{-1/4}` (one-sided: `L^{-1/2}`).
pub struct KroneckerUnit {
    pub(crate) beta2: f64,
    pub(crate) eps: f64,
    pub(crate) one_sided: bool,
    pub(crate) l: Matrix,
    pub(crate) r: Matrix,
    pub(crate) l_root: Option<Matrix>,
    pub(crate) r_root: Option<Matrix>,
}

impl KroneckerUnit {
    pub fn new(shape: (usize, usize), beta2: f64, eps: f64, one_sided: bool) -> Self {
        let (m, n) = shape;
        KroneckerUnit {
            beta2,
            eps,
            one_sided,
            l: Matrix::zeros(m, m),
            r: Matrix::zeros(n, n),
            l_root: None,
            r_root: None,
        }
    }
}

impl Preconditioner for KroneckerUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.l.scale_inplace(self.beta2);
        self.l.axpy(1.0, &a_at(g));
        if !self.one_sided {
            self.r.scale_inplace(self.beta2);
            self.r.axpy(1.0, &at_a(g));
        }
    }

    fn refresh(&mut self) -> bool {
        let p = if self.one_sided { 2.0 } else { 4.0 };
        self.l_root = Some(inv_pth_root(&self.l, p, self.eps));
        if !self.one_sided {
            self.r_root = Some(inv_pth_root(&self.r, 4.0, self.eps));
        }
        true
    }

    fn ready(&self) -> bool {
        self.l_root.is_some() && (self.one_sided || self.r_root.is_some())
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        let l_root = self.l_root.as_ref().expect("refresh before apply");
        if self.one_sided {
            matmul(l_root, g)
        } else {
            matmul(&matmul(l_root, g), self.r_root.as_ref().expect("refresh before apply"))
        }
    }

    fn mem_bytes(&self) -> usize {
        self.l.mem_bytes()
            + self.r.mem_bytes()
            + self.l_root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
            + self.r_root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
    }

    fn second_moment_bytes(&self) -> usize {
        self.l.mem_bytes() + self.r.mem_bytes()
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Kronecker {
            l: self.l.clone(),
            r: self.r.clone(),
            l_root: self.l_root.clone(),
            r_root: self.r_root.clone(),
        }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Kronecker { l, r, l_root, r_root } = state else {
            anyhow::bail!("state restore: non-Kronecker payload for a Kronecker unit");
        };
        let (m, n) = (self.l.rows(), self.r.rows());
        ensure_shape("L factor", &l, m, m)?;
        ensure_shape("R factor", &r, n, n)?;
        ensure_opt_shape("L root", &l_root, m, m)?;
        ensure_opt_shape("R root", &r_root, n, n)?;
        if self.one_sided {
            anyhow::ensure!(
                r_root.is_none(),
                "state restore: R root present for a one-sided Kronecker unit"
            );
        }
        self.l = l;
        self.r = r;
        self.l_root = l_root;
        self.r_root = r_root;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FD-sketched factors (S-Shampoo).
// ---------------------------------------------------------------------------

/// One side (L or R) of the factored S-Shampoo preconditioner.
pub(crate) enum Side {
    /// dim ≤ ℓ: exact EMA factor, spectral root cached.
    Exact { c: Matrix, root: Option<Matrix> },
    /// dim > ℓ: EW-FD sketch (Obs. 6), applied in factored form.
    Sketched { fd: FdSketch },
}

impl Side {
    pub(crate) fn new(dim: usize, rank: usize, beta2: f64) -> Side {
        if dim <= rank {
            Side::Exact { c: Matrix::zeros(dim, dim), root: None }
        } else {
            Side::Sketched { fd: FdSketch::new(dim, rank, beta2) }
        }
    }

    /// Update statistics with news factor Y (news = Y Yᵀ).
    pub(crate) fn update(&mut self, y: &Matrix, beta2: f64) {
        match self {
            Side::Exact { c, .. } => {
                c.scale_inplace(beta2);
                c.axpy(1.0, &a_at(y));
            }
            Side::Sketched { fd } => {
                fd.update(y);
            }
        }
    }

    /// Refresh any cached spectral roots (exact mode only; sketched sides
    /// apply their inverse roots directly from the factored form, so they
    /// are never stale). Returns whether an eigendecomposition ran.
    pub(crate) fn refresh_root(&mut self, eps: f64, p: f64) -> bool {
        if let Side::Exact { c, root } = self {
            *root = Some(inv_pth_root(c, p, eps));
            true
        } else {
            false
        }
    }

    pub(crate) fn has_root(&self) -> bool {
        match self {
            Side::Exact { root, .. } => root.is_some(),
            Side::Sketched { .. } => true,
        }
    }

    /// Apply this side's `(·)^{-1/p}` from the left: `C^{-1/p} X`
    /// (p = 4 two-sided Shampoo, p = 2 one-sided §3.4).
    pub(crate) fn apply_left(&self, x: &Matrix, eps: f64, p: f64) -> Matrix {
        match self {
            Side::Exact { root, .. } => matmul(root.as_ref().expect("root not ready"), x),
            Side::Sketched { fd } => {
                // L̃ = Ḡ + (ρ_{1:t} + ε) I, per Alg. 3 line 6 plus the ε
                // ridge of the initialization L̃₀ = εI.
                let pre = fd.shifted(fd.escaped_mass() + eps);
                pre.apply_inv_root_left(p, x)
            }
        }
    }

    /// Apply this side's `(·)^{-1/4}` from the right: `X C^{-1/4}`.
    pub(crate) fn apply_right(&self, x: &Matrix, eps: f64) -> Matrix {
        match self {
            Side::Exact { root, .. } => matmul(x, root.as_ref().expect("root not ready")),
            Side::Sketched { fd } => {
                let pre = fd.shifted(fd.escaped_mass() + eps);
                pre.apply_inv_root_right(4.0, x)
            }
        }
    }

    pub(crate) fn mem_bytes(&self) -> usize {
        match self {
            Side::Exact { c, root } => {
                c.mem_bytes() + root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
            }
            Side::Sketched { fd } => fd.mem_bytes(),
        }
    }

    pub(crate) fn second_moment_bytes(&self) -> usize {
        match self {
            Side::Exact { c, .. } => c.mem_bytes(),
            Side::Sketched { fd } => fd.mem_bytes(),
        }
    }

    /// Escaped mass (0 in exact mode) — diagnostics.
    pub(crate) fn escaped(&self) -> f64 {
        match self {
            Side::Exact { .. } => 0.0,
            Side::Sketched { fd } => fd.escaped_mass(),
        }
    }

    /// Snapshot this side's mutable state in its natural factored form.
    pub(crate) fn snapshot(&self) -> SideState {
        match self {
            Side::Exact { c, root } => SideState::Exact { c: c.clone(), root: root.clone() },
            Side::Sketched { fd } => SideState::Sketch(SketchState {
                basis: fd.basis().clone(),
                eigvals: fd.eigenvalues().to_vec(),
                escaped_mass: fd.escaped_mass(),
                last_rho: fd.last_escaped(),
                steps: fd.steps() as u64,
            }),
        }
    }

    /// Restore a [`Side::snapshot`]; the side mode (exact vs sketched)
    /// and every dimension must match this side's construction.
    pub(crate) fn restore(&mut self, state: SideState) -> anyhow::Result<()> {
        match (self, state) {
            (Side::Exact { c, root }, SideState::Exact { c: nc, root: nroot }) => {
                let d = c.rows();
                ensure_shape("exact side factor", &nc, d, d)?;
                ensure_opt_shape("exact side root", &nroot, d, d)?;
                *c = nc;
                *root = nroot;
            }
            (Side::Sketched { fd }, SideState::Sketch(s)) => {
                anyhow::ensure!(
                    s.basis.rows() == fd.dim() && s.basis.cols() == fd.rank(),
                    "state restore: sketch basis {}x{} != expected {}x{}",
                    s.basis.rows(),
                    s.basis.cols(),
                    fd.dim(),
                    fd.rank()
                );
                *fd = FdSketch::from_parts(
                    s.basis,
                    s.eigvals,
                    fd.decay(),
                    s.escaped_mass,
                    s.last_rho,
                    s.steps as usize,
                )?;
            }
            (Side::Exact { .. }, SideState::Sketch(_)) => {
                anyhow::bail!("state restore: sketch payload for an exact side")
            }
            (Side::Sketched { .. }, SideState::Exact { .. }) => {
                anyhow::bail!("state restore: exact payload for a sketched side")
            }
        }
        Ok(())
    }
}

/// Sketched S-Shampoo unit: an FD sketch (or exact small factor) per side.
pub struct SketchUnit {
    pub(crate) left: Side,
    pub(crate) right: Side,
    beta2: f64,
    eps: f64,
    one_sided: bool,
}

impl SketchUnit {
    pub fn new(shape: (usize, usize), rank: usize, beta2: f64, eps: f64, one_sided: bool) -> Self {
        let (m, n) = shape;
        SketchUnit {
            left: Side::new(m, rank, beta2),
            right: Side::new(n, rank, beta2),
            beta2,
            eps,
            one_sided,
        }
    }

    fn left_p(&self) -> f64 {
        if self.one_sided {
            2.0
        } else {
            4.0
        }
    }

    /// Cumulative escaped mass (left, right) — E3/E9 diagnostics.
    pub fn escaped(&self) -> (f64, f64) {
        (self.left.escaped(), self.right.escaped())
    }
}

impl Preconditioner for SketchUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.left.update(g, self.beta2);
        if !self.one_sided {
            self.right.update(&g.t(), self.beta2);
        }
    }

    fn refresh(&mut self) -> bool {
        let mut did = self.left.refresh_root(self.eps, self.left_p());
        if !self.one_sided {
            did |= self.right.refresh_root(self.eps, 4.0);
        }
        did
    }

    fn ready(&self) -> bool {
        self.left.has_root() && (self.one_sided || self.right.has_root())
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        // L̃^{-1/4} G R̃^{-1/4} in factored form, O(mnℓ)
        // (one-sided: L̃^{-1/2} G).
        let half = self.left.apply_left(g, self.eps, self.left_p());
        if self.one_sided {
            half
        } else {
            self.right.apply_right(&half, self.eps)
        }
    }

    fn mem_bytes(&self) -> usize {
        self.left.mem_bytes() + self.right.mem_bytes()
    }

    fn second_moment_bytes(&self) -> usize {
        self.left.second_moment_bytes() + self.right.second_moment_bytes()
    }

    fn sketches(&self) -> Vec<&FdSketch> {
        let mut out = vec![];
        if let Side::Sketched { fd } = &self.left {
            out.push(fd);
        }
        if let Side::Sketched { fd } = &self.right {
            out.push(fd);
        }
        out
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Sketch { left: self.left.snapshot(), right: self.right.snapshot() }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Sketch { left, right } = state else {
            anyhow::bail!("state restore: non-sketch payload for a sketch unit");
        };
        self.left.restore(left)?;
        self.right.restore(right)
    }
}

// ---------------------------------------------------------------------------
// Diagonal (Adam) unit.
// ---------------------------------------------------------------------------

/// Diagonal Adam unit: first/second-moment EMAs with bias correction.
///
/// `apply` returns the full Adam direction `m̂/(√v̂ + ε)`; driven with
/// grafting off and driver momentum β₁ = 0, the engine step reproduces
/// the fused [`Adam`](super::Adam) bitwise (blocking included — the
/// update is elementwise).
pub struct AdamUnit {
    beta1: f64,
    beta2: f64,
    eps: f64,
    m: Matrix,
    v: Matrix,
    t: usize,
}

impl AdamUnit {
    pub fn new(shape: (usize, usize), beta1: f64, beta2: f64, eps: f64) -> Self {
        let (r, c) = shape;
        AdamUnit { beta1, beta2, eps, m: Matrix::zeros(r, c), v: Matrix::zeros(r, c), t: 0 }
    }
}

impl Preconditioner for AdamUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.t += 1;
        let ms = self.m.as_mut_slice();
        let vs = self.v.as_mut_slice();
        let gs = g.as_slice();
        for j in 0..gs.len() {
            ms[j] = self.beta1 * ms[j] + (1.0 - self.beta1) * gs[j];
            vs[j] = self.beta2 * vs[j] + (1.0 - self.beta2) * gs[j] * gs[j];
        }
    }

    fn refresh(&mut self) -> bool {
        false
    }

    fn ready(&self) -> bool {
        true
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let mut out = Matrix::zeros(g.rows(), g.cols());
        let os = out.as_mut_slice();
        let ms = self.m.as_slice();
        let vs = self.v.as_slice();
        for j in 0..os.len() {
            let mhat = ms[j] / bc1;
            let vhat = vs[j] / bc2;
            os[j] = mhat / (vhat.sqrt() + self.eps);
        }
        out
    }

    fn mem_bytes(&self) -> usize {
        self.m.mem_bytes() + self.v.mem_bytes()
    }

    fn second_moment_bytes(&self) -> usize {
        self.v.mem_bytes()
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Diag { m: self.m.clone(), v: self.v.clone(), t: self.t as u64 }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Diag { m, v, t } = state else {
            anyhow::bail!("state restore: non-diagonal payload for an Adam unit");
        };
        let (r, c) = (self.m.rows(), self.m.cols());
        ensure_shape("Adam first moment", &m, r, c)?;
        ensure_shape("Adam second moment", &v, r, c)?;
        self.m = m;
        self.v = v;
        self.t = t as usize;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared per-block step driver.
// ---------------------------------------------------------------------------

/// Per-block optimizer state driven by the engine: a preconditioner unit
/// plus the first-order companions (grafting, momentum).
pub struct BlockState {
    pub unit: Box<dyn Preconditioner>,
    pub graft: Graft,
    pub mu: Matrix,
    /// Scratch gathered parameter block (engine-owned copy).
    pub(crate) param: Matrix,
    /// Scratch gathered gradient block.
    pub(crate) grad: Matrix,
}

impl BlockState {
    pub fn new(
        unit: Box<dyn Preconditioner>,
        graft: GraftType,
        shape: (usize, usize),
        beta2: f64,
    ) -> Self {
        let (r, c) = shape;
        BlockState {
            unit,
            graft: Graft::new(graft, (r, c), beta2),
            mu: Matrix::zeros(r, c),
            param: Matrix::zeros(r, c),
            grad: Matrix::zeros(r, c),
        }
    }

    /// Total heap bytes of this block's optimizer state (unit + graft +
    /// momentum + gathered scratch) — the one accounting formula shared
    /// by the in-process executor and the shard workers.
    pub fn mem_bytes(&self) -> usize {
        self.unit.mem_bytes()
            + self.graft.mem_bytes()
            + self.mu.mem_bytes()
            + self.param.mem_bytes()
            + self.grad.mem_bytes()
    }

    /// Bytes of second-moment (covariance) state only.
    pub fn second_moment_bytes(&self) -> usize {
        self.unit.second_moment_bytes()
    }

    /// Snapshot the block's full mutable optimizer state: the unit's
    /// typed payload plus the first-order companions (momentum, grafting
    /// accumulator). Scratch buffers never travel.
    pub fn snapshot(&self) -> BlockStateSnap {
        let (graft_v, graft_t) = self.graft.snapshot();
        BlockStateSnap { unit: self.unit.state_payload(), mu: self.mu.clone(), graft_v, graft_t }
    }

    /// Restore a [`BlockState::snapshot`]; every shape/kind must match
    /// this block's construction. On success the block steps bitwise
    /// identically to the snapshotted one. A failed restore may leave
    /// the block partially updated — callers treat `Err` as fatal.
    pub fn restore(&mut self, snap: BlockStateSnap) -> anyhow::Result<()> {
        ensure_shape("momentum", &snap.mu, self.mu.rows(), self.mu.cols())?;
        self.unit.restore_payload(snap.unit)?;
        self.graft.restore(snap.graft_v, snap.graft_t)?;
        self.mu = snap.mu;
        Ok(())
    }
}

/// Full serialized optimizer state of one block: the preconditioner
/// unit's [`PrecondState`] plus momentum and grafting companions. This is
/// what crosses the [`crate::optim::engine::BlockExecutor`] state
/// boundary and lands in v2 checkpoints.
#[derive(Clone, Debug)]
pub struct BlockStateSnap {
    pub unit: PrecondState,
    pub mu: Matrix,
    pub graft_v: Option<Matrix>,
    pub graft_t: u64,
}

/// Parameters controlling one driven step (shared by all blocks).
///
/// Public because it crosses the [`crate::optim::engine::BlockExecutor`]
/// boundary: the engine computes one `StepCtx` per block (including the
/// block's staggered `refresh_due` slot) and executors — in-process or
/// cross-process — drive [`drive_block`]-equivalent logic from it.
#[derive(Clone, Copy, Debug)]
pub struct StepCtx {
    pub t: usize,
    pub scale: f64,
    pub preconditioning: bool,
    pub refresh_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub stat_due: bool,
    pub graft: GraftType,
}

/// One block step: the exact Shampoo/App. C flow — statistics, (possibly
/// stale) root refresh, graft, precondition, transplant, momentum,
/// decoupled weight decay. Returns `true` when an eigendecomposition ran
/// (the engine counts refreshes for its amortization accounting).
///
/// Allocation-discipline: the unclipped path borrows the gathered
/// gradient in place, and `GraftType::None` (whose graft "step" is a
/// full clone of the gradient) skips the graft companion entirely.
pub(crate) fn drive_block(st: &mut BlockState, ctx: &StepCtx) -> bool {
    let BlockState { unit, graft, mu, param, grad } = st;
    let scaled;
    let g: &Matrix = if ctx.scale != 1.0 {
        scaled = grad.scale(ctx.scale);
        &scaled
    } else {
        grad
    };
    if ctx.stat_due {
        unit.ingest(g);
    }
    let mut refreshed = false;
    if ctx.preconditioning && (!unit.ready() || ctx.refresh_due) {
        refreshed = unit.refresh();
    }
    let update = if ctx.preconditioning {
        let dir = unit.apply(g);
        if ctx.graft == GraftType::None {
            dir
        } else {
            transplant(&graft.step(g), &dir)
        }
    } else {
        graft.step(g)
    };
    mu.scale_inplace(ctx.beta1);
    mu.axpy(1.0 - ctx.beta1, &update);
    let ps = param.as_mut_slice();
    let ms = mu.as_slice();
    for j in 0..ps.len() {
        ps[j] -= ctx.lr * (ms[j] + ctx.weight_decay * ps[j]);
    }
    refreshed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn kronecker_unit_whitens_after_refresh() {
        let mut rng = Pcg64::new(200);
        let mut unit = KroneckerUnit::new((6, 4), 1.0, 1e-9, false);
        let g = Matrix::randn(6, 4, &mut rng);
        assert!(!unit.ready());
        unit.ingest(&g);
        unit.refresh();
        assert!(unit.ready());
        // L^{-1/4} G R^{-1/4} with L = GGᵀ, R = GᵀG has unit-scale spectrum:
        // for G = UΣVᵀ the preconditioned direction is UVᵀ (+ eps ridge).
        let dir = unit.apply(&g);
        let gram = crate::tensor::at_a(&dir);
        for i in 0..4 {
            assert!((gram[(i, i)] - 1.0).abs() < 1e-3, "diag {}", gram[(i, i)]);
        }
    }

    #[test]
    fn kronecker_one_sided_skips_right() {
        let mut rng = Pcg64::new(201);
        let mut unit = KroneckerUnit::new((5, 3), 0.999, 1e-6, true);
        unit.ingest(&Matrix::randn(5, 3, &mut rng));
        unit.refresh();
        assert!(unit.ready());
        assert_eq!(unit.r.fro_norm(), 0.0);
        assert!(unit.r_root.is_none());
    }

    #[test]
    fn sketch_unit_exposes_fd_sketches() {
        // 10×2 with rank 4: left side is sketched (10 > 4), right exact.
        let mut unit = SketchUnit::new((10, 2), 4, 0.999, 1e-6, false);
        assert_eq!(unit.sketches().len(), 1);
        let mut rng = Pcg64::new(202);
        unit.ingest(&Matrix::randn(10, 2, &mut rng));
        assert!(unit.sketches()[0].steps() > 0);
    }

    /// Drive two identical blocks a few steps, snapshot/restore one into
    /// a fresh block, then keep driving both and demand bitwise equality.
    fn assert_snapshot_restore_is_bitwise(mk: impl Fn() -> BlockState, shape: (usize, usize)) {
        let mut rng = Pcg64::new(205);
        let mut a = mk();
        let ctx = StepCtx {
            t: 0,
            scale: 1.0,
            preconditioning: true,
            refresh_due: true,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 0.001,
            stat_due: true,
            graft: GraftType::Rmsprop,
        };
        for t in 1..=5 {
            a.grad = Matrix::randn(shape.0, shape.1, &mut rng);
            drive_block(&mut a, &StepCtx { t, refresh_due: t % 2 == 0, ..ctx });
        }
        let mut b = mk();
        b.restore(a.snapshot()).unwrap();
        b.param = a.param.clone();
        assert_eq!(a.mem_bytes(), b.mem_bytes());
        for t in 6..=10 {
            let g = Matrix::randn(shape.0, shape.1, &mut rng);
            a.grad = g.clone();
            b.grad = g;
            let c = StepCtx { t, refresh_due: t % 2 == 0, ..ctx };
            drive_block(&mut a, &c);
            drive_block(&mut b, &c);
            assert_eq!(a.param.max_diff(&b.param), 0.0, "diverged at t={t}");
            assert_eq!(a.mu.max_diff(&b.mu), 0.0);
        }
    }

    #[test]
    fn kronecker_state_roundtrips_bitwise() {
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(KroneckerUnit::new((6, 4), 0.999, 1e-9, false)),
                    GraftType::Rmsprop,
                    (6, 4),
                    0.999,
                )
            },
            (6, 4),
        );
    }

    #[test]
    fn sketch_state_roundtrips_bitwise() {
        // 10×3 at rank 4: left sketched, right exact — both side modes.
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(SketchUnit::new((10, 3), 4, 0.999, 1e-9, false)),
                    GraftType::Rmsprop,
                    (10, 3),
                    0.999,
                )
            },
            (10, 3),
        );
    }

    #[test]
    fn adam_state_roundtrips_bitwise() {
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(AdamUnit::new((5, 5), 0.9, 0.999, 1e-8)),
                    GraftType::Rmsprop,
                    (5, 5),
                    0.999,
                )
            },
            (5, 5),
        );
    }

    #[test]
    fn state_restore_rejects_mismatched_payloads() {
        // Wrong kind.
        let mut kron = KroneckerUnit::new((4, 4), 0.999, 1e-9, false);
        let adam = AdamUnit::new((4, 4), 0.9, 0.999, 1e-8);
        assert!(kron.restore_payload(adam.state_payload()).is_err());
        // Wrong shape.
        let other = KroneckerUnit::new((5, 4), 0.999, 1e-9, false);
        assert!(kron.restore_payload(other.state_payload()).is_err());
        // One-sided unit refuses a right root.
        let mut one_sided = KroneckerUnit::new((4, 4), 0.999, 1e-9, true);
        let mut two_sided = KroneckerUnit::new((4, 4), 0.999, 1e-9, false);
        let mut rng = Pcg64::new(206);
        two_sided.ingest(&Matrix::randn(4, 4, &mut rng));
        two_sided.refresh();
        assert!(one_sided.restore_payload(two_sided.state_payload()).is_err());
        // Sketched/exact side mode mismatch (rank 4: dim 10 sketched,
        // dim 3 exact — transposed unit flips the modes).
        let mut unit = SketchUnit::new((10, 3), 4, 0.999, 1e-9, false);
        let flipped = SketchUnit::new((3, 10), 4, 0.999, 1e-9, false);
        assert!(unit.restore_payload(flipped.state_payload()).is_err());
        // Adversarial sketch rank: basis with the wrong column count.
        let PrecondState::Sketch { left, right } = unit.state_payload() else { unreachable!() };
        let SideState::Sketch(mut s) = left else { unreachable!() };
        s.basis = Matrix::zeros(10, 7);
        s.eigvals = vec![0.0; 7];
        assert!(unit
            .restore_payload(PrecondState::Sketch { left: SideState::Sketch(s), right })
            .is_err());
        // Graft companion shape mismatch surfaces through BlockState.
        let mk = || {
            BlockState::new(
                Box::new(AdamUnit::new((3, 3), 0.9, 0.999, 1e-8)),
                GraftType::Rmsprop,
                (3, 3),
                0.999,
            )
        };
        let mut blk = mk();
        let mut snap = mk().snapshot();
        snap.graft_v = Some(Matrix::zeros(2, 2));
        assert!(blk.restore(snap).is_err());
        let mut snap = mk().snapshot();
        snap.mu = Matrix::zeros(9, 1);
        assert!(blk.restore(snap).is_err());
    }

    #[test]
    fn adam_unit_matches_closed_form_first_step() {
        let mut unit = AdamUnit::new((1, 1), 0.9, 0.999, 1e-8);
        let g = Matrix::from_rows(&[vec![1234.5]]);
        unit.ingest(&g);
        let dir = unit.apply(&g);
        // Bias correction ⇒ first direction magnitude ≈ 1 for any g scale.
        assert!((dir[(0, 0)].abs() - 1.0).abs() < 1e-6);
    }
}
