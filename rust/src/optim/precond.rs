//! The unified preconditioner interface behind the tensor-world optimizer
//! family.
//!
//! Every second-order method in this repository decomposes into the same
//! three per-tensor (or per-block) operations:
//!
//! 1. **ingest** — fold a gradient into the second-moment statistics
//!    (exact Kronecker factors, FD sketches, or a diagonal accumulator);
//! 2. **refresh** — recompute the expensive derived state (inverse-root
//!    eigendecompositions) from the current statistics;
//! 3. **apply** — precondition a gradient with the derived state.
//!
//! [`Preconditioner`] captures that contract. [`Shampoo`](super::Shampoo)
//! and [`SShampoo`](super::SShampoo) drive the units serially with their
//! paper-faithful cadences; the parallel block engine
//! ([`super::engine::PrecondEngine`]) drives the very same units across a
//! thread pool with a staggered stale-refresh schedule, so the eigh calls
//! of different blocks overlap instead of serializing the step (§3.4 /
//! §7 amortization).
//!
//! Splitting ingest/refresh/apply is what makes staleness a *schedule*
//! decision rather than an algorithm change: a unit is always safe to
//! apply with roots computed from older statistics, which is exactly the
//! production Shampoo trick (`precond_interval` in App. C).

use super::grafting::{transplant, Graft, GraftType};
use crate::sketch::FdSketch;
use crate::tensor::{a_at, a_bt, at_a, at_b, eigh, inv_pth_root, matmul, Matrix};

/// Per-tensor/per-block preconditioner unit: statistics + derived state.
///
/// `Send` so the block engine can move units across worker threads.
pub trait Preconditioner: Send {
    /// Fold gradient `g` into the second-moment statistics.
    fn ingest(&mut self, g: &Matrix);

    /// Recompute derived state (inverse roots) from current statistics.
    /// Returns `true` only when real work ran (an eigendecomposition) —
    /// no-op refreshes (diagonal units, fully-sketched sides) return
    /// `false` so the engine's amortization accounting stays honest.
    fn refresh(&mut self) -> bool;

    /// Whether derived state exists (first apply must be preceded by a
    /// refresh for units with cached roots).
    fn ready(&self) -> bool;

    /// EKFAC-style inter-refresh correction hook: fold gradient `g`'s
    /// second moments *in the current stale eigenbasis* into corrected
    /// diagonal scales (George et al., "Fast Approximate Natural Gradient
    /// Descent in a Kronecker-factored Eigenbasis"). Called once per
    /// preconditioned step, after any refresh and before `apply`. Default
    /// no-op: only units constructed with ekfac on maintain a corrector.
    fn track(&mut self, _g: &Matrix) {}

    /// Preconditioned direction for gradient `g`.
    fn apply(&self, g: &Matrix) -> Matrix;

    /// Total heap bytes of unit state.
    fn mem_bytes(&self) -> usize;

    /// Bytes of second-moment (covariance) state only.
    fn second_moment_bytes(&self) -> usize;

    /// Live FD sketches backing this unit (sketched families only) —
    /// exposed for invariant checks and diagnostics.
    fn sketches(&self) -> Vec<&FdSketch> {
        vec![]
    }

    /// Serializable snapshot of the unit's mutable state — the typed
    /// payload behind wire protocol v4 and checkpoint format v2. Sketched
    /// sides export their rank-ℓ factors (O(dℓ)), never a materialized
    /// d×d covariance.
    fn state_payload(&self) -> PrecondState;

    /// Restore a [`Preconditioner::state_payload`] snapshot. The payload
    /// kind and every shape/rank must match this unit's construction
    /// (hyperparameters are construction-owned and never travel); on
    /// success the unit is bitwise identical to the snapshotted one. A
    /// failed restore may leave the unit partially updated — callers
    /// treat an `Err` as fatal for the hosting engine.
    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()>;
}

// ---------------------------------------------------------------------------
// Typed state snapshots (wire v4 / checkpoint v2 payloads).
// ---------------------------------------------------------------------------

/// Snapshot of one preconditioner unit's mutable state, in the unit's
/// natural factored form. This is the *semantic* payload type; the wire
/// and checkpoint codecs ([`crate::coordinator::wire::StatePayload`])
/// encode it without ever densifying sketched sides.
#[derive(Clone, Debug)]
pub enum PrecondState {
    /// Exact Kronecker factors, their cached inverse roots, and (ekfac
    /// units only) the per-factor inter-refresh correctors.
    Kronecker {
        l: Matrix,
        r: Matrix,
        l_root: Option<Matrix>,
        r_root: Option<Matrix>,
        l_corr: Option<EigCorrState>,
        r_corr: Option<EigCorrState>,
    },
    /// Per-side sketched (or small-exact) factors.
    Sketch { left: SideState, right: SideState },
    /// Diagonal Adam moments + step counter.
    Diag { m: Matrix, v: Matrix, t: u64 },
}

/// One side of a [`PrecondState::Sketch`] snapshot.
#[derive(Clone, Debug)]
pub enum SideState {
    /// dim ≤ ℓ: exact factor plus cached root (and ekfac corrector).
    Exact { c: Matrix, root: Option<Matrix>, corr: Option<EigCorrState> },
    /// dim > ℓ: the FD sketch's factored state (and ekfac corrector).
    Sketch { sketch: SketchState, corr: Option<SketchCorrState> },
}

/// Factored FD sketch state: O(dℓ) basis + ℓ eigenvalues + the RFD-style
/// escaped-mass accumulator that makes the sketch a self-contained
/// serialization unit (restore needs no replay of the stream).
#[derive(Clone, Debug)]
pub struct SketchState {
    /// Orthonormal eigenbasis, d×ℓ.
    pub basis: Matrix,
    /// Eigenvalues, descending, length ℓ.
    pub eigvals: Vec<f64>,
    /// Cumulative escaped mass ρ_{1:t}.
    pub escaped_mass: f64,
    /// Escaped mass of the most recent update.
    pub last_rho: f64,
    /// Update counter.
    pub steps: u64,
}

fn ensure_shape(what: &str, m: &Matrix, rows: usize, cols: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        m.rows() == rows && m.cols() == cols,
        "state restore: {what} shape {}x{} != expected {rows}x{cols}",
        m.rows(),
        m.cols()
    );
    Ok(())
}

fn ensure_opt_shape(
    what: &str,
    m: &Option<Matrix>,
    rows: usize,
    cols: usize,
) -> anyhow::Result<()> {
    if let Some(m) = m {
        ensure_shape(what, m, rows, cols)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// EKFAC inter-refresh correctors.
// ---------------------------------------------------------------------------

/// Snapshot of an [`EigCorr`] — travels with [`PrecondState::Kronecker`]
/// and exact [`SideState`]s when the owning unit runs with ekfac on.
#[derive(Clone, Debug)]
pub struct EigCorrState {
    /// Stale eigenbasis, d×d.
    pub basis: Matrix,
    /// Corrected per-direction second moments, length d.
    pub diag: Vec<f64>,
}

/// Snapshot of a [`SketchCorr`].
#[derive(Clone, Debug)]
pub struct SketchCorrState {
    /// Corrected moments over the FD basis columns, length ℓ.
    pub diag: Vec<f64>,
    /// Corrected complement (escaped-mass) moment.
    pub tail: f64,
}

/// EKFAC corrector for an exact factor: the factor's stale eigenbasis plus
/// per-direction corrected second moments. Between eigendecompositions the
/// frozen eigenvalues drift away from the true curvature; folding each
/// step's gradient moments into `diag` (in the *stale* basis) tracks the
/// diagonal of `Uᵀ C U` exactly, which is what lets the refresh interval
/// stretch 4 → 32+ without quality loss.
pub(crate) struct EigCorr {
    /// Stale eigenbasis U (d×d), columns ordered like `diag`.
    basis: Matrix,
    /// Corrected second moments diag(Uᵀ C U), same EMA decay as the factor.
    diag: Vec<f64>,
}

impl EigCorr {
    /// Reseed from a fresh eigendecomposition of the factor: the corrected
    /// diagonal starts at the true eigenvalues, so the corrected apply
    /// coincides with the frozen-root apply at refresh time.
    fn reseed(c: &Matrix) -> EigCorr {
        let e = eigh(c);
        EigCorr { basis: e.q, diag: e.w }
    }

    /// Spectral scales `(max(dᵢ,0) + ε)^{-1/p}` — the same ridge
    /// convention as [`inv_pth_root`].
    fn scales(&self, eps: f64, p: f64) -> Vec<f64> {
        self.diag.iter().map(|&d| (d.max(0.0) + eps).powf(-1.0 / p)).collect()
    }

    /// Corrected left inverse-root apply: `U f(diag) Uᵀ X`.
    fn apply_left(&self, x: &Matrix, eps: f64, p: f64) -> Matrix {
        let mut proj = at_b(&self.basis, x);
        for (j, s) in self.scales(eps, p).into_iter().enumerate() {
            for v in proj.row_mut(j) {
                *v *= s;
            }
        }
        matmul(&self.basis, &proj)
    }

    /// Corrected right inverse-root apply: `X U f(diag) Uᵀ`.
    fn apply_right(&self, x: &Matrix, eps: f64, p: f64) -> Matrix {
        let mut proj = matmul(x, &self.basis);
        for (j, s) in self.scales(eps, p).into_iter().enumerate() {
            for i in 0..proj.rows() {
                proj[(i, j)] *= s;
            }
        }
        a_bt(&proj, &self.basis)
    }

    /// Fold row-space moments: `diagᵢ ← β₂·diagᵢ + ‖uᵢᵀG‖²`, the diagonal
    /// of the factor's own EMA update `β₂L + GGᵀ` seen in the stale basis.
    fn track_left(&mut self, g: &Matrix, beta2: f64) {
        let proj = at_b(&self.basis, g);
        let (rows, cols) = (proj.rows(), proj.cols());
        let ps = proj.as_slice();
        for i in 0..rows {
            let mut s = 0.0;
            for j in 0..cols {
                s += ps[i * cols + j] * ps[i * cols + j];
            }
            self.diag[i] = beta2 * self.diag[i] + s;
        }
    }

    /// Column-space mirror: `diagₖ ← β₂·diagₖ + ‖Gvₖ‖²` (the diagonal of
    /// `β₂R + GᵀG` in the stale basis).
    fn track_right(&mut self, g: &Matrix, beta2: f64) {
        let proj = matmul(g, &self.basis);
        let (rows, cols) = (proj.rows(), proj.cols());
        let ps = proj.as_slice();
        for k in 0..cols {
            let mut s = 0.0;
            for i in 0..rows {
                s += ps[i * cols + k] * ps[i * cols + k];
            }
            self.diag[k] = beta2 * self.diag[k] + s;
        }
    }

    fn mem_bytes(&self) -> usize {
        self.basis.mem_bytes() + self.diag.len() * std::mem::size_of::<f64>()
    }

    fn snapshot(&self) -> EigCorrState {
        EigCorrState { basis: self.basis.clone(), diag: self.diag.clone() }
    }

    fn restore(what: &str, s: EigCorrState, dim: usize) -> anyhow::Result<EigCorr> {
        ensure_shape(what, &s.basis, dim, dim)?;
        anyhow::ensure!(
            s.diag.len() == dim,
            "state restore: {what} diagonal length {} != expected {dim}",
            s.diag.len()
        );
        Ok(EigCorr { basis: s.basis, diag: s.diag })
    }
}

/// EKFAC corrector for a sketched side: corrected second moments over the
/// rank-ℓ FD basis columns plus a scalar tail — the per-direction moment
/// of the complement subspace, playing the escaped-mass shift's role
/// between sketch updates. The basis itself lives in the side's
/// [`FdSketch`]; this struct is O(ℓ).
pub(crate) struct SketchCorr {
    /// Corrected moments over the FD basis columns (length ℓ).
    diag: Vec<f64>,
    /// Corrected complement (escaped-mass) moment.
    tail: f64,
}

impl SketchCorr {
    /// Reseed from a freshly shrunk sketch: eigenvalues + escaped mass,
    /// so the corrected apply coincides with the legacy factored apply at
    /// sketch-update time.
    fn reseed(fd: &FdSketch) -> SketchCorr {
        SketchCorr { diag: fd.eigenvalues().to_vec(), tail: fd.escaped_mass() }
    }

    /// Coefficients of the shifted factored apply with the corrected
    /// diagonal in place of the frozen eigenvalues: per-column
    /// `f(dⱼ + shift) − f(shift)` plus the complement scale `f(shift)`,
    /// `f(λ) = λ^{-1/p}`, `shift = max(tail,0) + ε`. Zero basis columns
    /// carry d = 0 and so a zero coefficient — harmless.
    fn coeffs(&self, eps: f64, p: f64) -> (Vec<f64>, f64) {
        let shift = self.tail.max(0.0) + eps;
        let comp = shift.powf(-1.0 / p);
        let coeffs =
            self.diag.iter().map(|&d| (d.max(0.0) + shift).powf(-1.0 / p) - comp).collect();
        (coeffs, comp)
    }

    /// Corrected `L̃^{-1/p} X` over basis `u` — the factored-apply
    /// template of [`crate::sketch::FactoredPsd`] with corrected scales.
    fn apply_left(&self, u: &Matrix, x: &Matrix, eps: f64, p: f64) -> Matrix {
        let (coeffs, comp) = self.coeffs(eps, p);
        let mut y = x.scale(comp);
        let mut proj = at_b(u, x);
        for (j, &cj) in coeffs.iter().enumerate() {
            for v in proj.row_mut(j) {
                *v *= cj;
            }
        }
        y.axpy(1.0, &matmul(u, &proj));
        y
    }

    /// Corrected `X R̃^{-1/p}` over basis `u`.
    fn apply_right(&self, u: &Matrix, x: &Matrix, eps: f64, p: f64) -> Matrix {
        let (coeffs, comp) = self.coeffs(eps, p);
        let mut y = x.scale(comp);
        let mut proj = matmul(x, u);
        for (j, &cj) in coeffs.iter().enumerate() {
            for i in 0..proj.rows() {
                proj[(i, j)] *= cj;
            }
        }
        y.axpy(1.0, &a_bt(&proj, u));
        y
    }

    /// Fold row-space moments in the stale sketch basis plus the
    /// complement residual averaged over the d−ℓ escaped directions.
    fn track_left(&mut self, u: &Matrix, g: &Matrix, beta2: f64) {
        let proj = at_b(u, g);
        let ps = proj.as_slice();
        let (l, n) = (proj.rows(), proj.cols());
        let mut captured = 0.0;
        for i in 0..l {
            let mut s = 0.0;
            for j in 0..n {
                s += ps[i * n + j] * ps[i * n + j];
            }
            captured += s;
            self.diag[i] = beta2 * self.diag[i] + s;
        }
        self.fold_tail(g, captured, u.rows(), l, beta2);
    }

    /// Column-space mirror over basis `u` (dim×ℓ, dim = cols of `g`).
    fn track_right(&mut self, u: &Matrix, g: &Matrix, beta2: f64) {
        let proj = matmul(g, u);
        let ps = proj.as_slice();
        let (m, l) = (proj.rows(), proj.cols());
        let mut captured = 0.0;
        for k in 0..l {
            let mut s = 0.0;
            for i in 0..m {
                s += ps[i * l + k] * ps[i * l + k];
            }
            captured += s;
            self.diag[k] = beta2 * self.diag[k] + s;
        }
        self.fold_tail(g, captured, u.rows(), l, beta2);
    }

    fn fold_tail(&mut self, g: &Matrix, captured: f64, dim: usize, rank: usize, beta2: f64) {
        let mut total = 0.0;
        for &v in g.as_slice() {
            total += v * v;
        }
        // Sketched sides always have dim > ℓ; the complement moment is
        // the per-direction average of the mass the basis misses.
        let resid = (total - captured).max(0.0);
        self.tail = beta2 * self.tail + resid / (dim - rank) as f64;
    }

    fn mem_bytes(&self) -> usize {
        (self.diag.len() + 1) * std::mem::size_of::<f64>()
    }

    fn snapshot(&self) -> SketchCorrState {
        SketchCorrState { diag: self.diag.clone(), tail: self.tail }
    }

    fn restore(s: SketchCorrState, rank: usize) -> anyhow::Result<SketchCorr> {
        anyhow::ensure!(
            s.diag.len() == rank,
            "state restore: sketch corrector length {} != expected rank {rank}",
            s.diag.len()
        );
        Ok(SketchCorr { diag: s.diag, tail: s.tail })
    }
}

// ---------------------------------------------------------------------------
// Exact Kronecker factors (Shampoo).
// ---------------------------------------------------------------------------

/// Exact Shampoo unit: EMA factors `L ← β₂L + G Gᵀ`, `R ← β₂R + GᵀG` with
/// cached inverse roots `L^{-1/4}` / `R^{-1/4}` (one-sided: `L^{-1/2}`).
pub struct KroneckerUnit {
    pub(crate) beta2: f64,
    pub(crate) eps: f64,
    pub(crate) one_sided: bool,
    pub(crate) ekfac: bool,
    pub(crate) l: Matrix,
    pub(crate) r: Matrix,
    pub(crate) l_root: Option<Matrix>,
    pub(crate) r_root: Option<Matrix>,
    pub(crate) l_corr: Option<EigCorr>,
    pub(crate) r_corr: Option<EigCorr>,
}

impl KroneckerUnit {
    pub fn new(shape: (usize, usize), beta2: f64, eps: f64, one_sided: bool) -> Self {
        let (m, n) = shape;
        KroneckerUnit {
            beta2,
            eps,
            one_sided,
            ekfac: false,
            l: Matrix::zeros(m, m),
            r: Matrix::zeros(n, n),
            l_root: None,
            r_root: None,
            l_corr: None,
            r_corr: None,
        }
    }

    /// Enable the EKFAC-style inter-refresh corrector (builder style;
    /// resolved once at engine construction, never toggled mid-run).
    pub fn ekfac(mut self, on: bool) -> Self {
        self.ekfac = on;
        self
    }
}

impl Preconditioner for KroneckerUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.l.scale_inplace(self.beta2);
        self.l.axpy(1.0, &a_at(g));
        if !self.one_sided {
            self.r.scale_inplace(self.beta2);
            self.r.axpy(1.0, &at_a(g));
        }
    }

    fn refresh(&mut self) -> bool {
        if self.ekfac {
            // EKFAC mode keeps the eigenbasis + corrected diagonal instead
            // of a frozen inverse root; `track` re-tightens the diagonal
            // every step between these (now rare) eigendecompositions.
            self.l_corr = Some(EigCorr::reseed(&self.l));
            if !self.one_sided {
                self.r_corr = Some(EigCorr::reseed(&self.r));
            }
            return true;
        }
        let p = if self.one_sided { 2.0 } else { 4.0 };
        self.l_root = Some(inv_pth_root(&self.l, p, self.eps));
        if !self.one_sided {
            self.r_root = Some(inv_pth_root(&self.r, 4.0, self.eps));
        }
        true
    }

    fn ready(&self) -> bool {
        if self.ekfac {
            self.l_corr.is_some() && (self.one_sided || self.r_corr.is_some())
        } else {
            self.l_root.is_some() && (self.one_sided || self.r_root.is_some())
        }
    }

    fn track(&mut self, g: &Matrix) {
        if !self.ekfac {
            return;
        }
        if let Some(c) = &mut self.l_corr {
            c.track_left(g, self.beta2);
        }
        if !self.one_sided {
            if let Some(c) = &mut self.r_corr {
                c.track_right(g, self.beta2);
            }
        }
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        if self.ekfac {
            let p = if self.one_sided { 2.0 } else { 4.0 };
            let lc = self.l_corr.as_ref().expect("refresh before apply");
            let half = lc.apply_left(g, self.eps, p);
            return if self.one_sided {
                half
            } else {
                let rc = self.r_corr.as_ref().expect("refresh before apply");
                rc.apply_right(&half, self.eps, 4.0)
            };
        }
        let l_root = self.l_root.as_ref().expect("refresh before apply");
        if self.one_sided {
            matmul(l_root, g)
        } else {
            matmul(&matmul(l_root, g), self.r_root.as_ref().expect("refresh before apply"))
        }
    }

    fn mem_bytes(&self) -> usize {
        self.l.mem_bytes()
            + self.r.mem_bytes()
            + self.l_root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
            + self.r_root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
            + self.l_corr.as_ref().map(|c| c.mem_bytes()).unwrap_or(0)
            + self.r_corr.as_ref().map(|c| c.mem_bytes()).unwrap_or(0)
    }

    fn second_moment_bytes(&self) -> usize {
        self.l.mem_bytes() + self.r.mem_bytes()
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Kronecker {
            l: self.l.clone(),
            r: self.r.clone(),
            l_root: self.l_root.clone(),
            r_root: self.r_root.clone(),
            l_corr: self.l_corr.as_ref().map(|c| c.snapshot()),
            r_corr: self.r_corr.as_ref().map(|c| c.snapshot()),
        }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Kronecker { l, r, l_root, r_root, l_corr, r_corr } = state else {
            anyhow::bail!("state restore: non-Kronecker payload for a Kronecker unit");
        };
        let (m, n) = (self.l.rows(), self.r.rows());
        ensure_shape("L factor", &l, m, m)?;
        ensure_shape("R factor", &r, n, n)?;
        ensure_opt_shape("L root", &l_root, m, m)?;
        ensure_opt_shape("R root", &r_root, n, n)?;
        if self.one_sided {
            anyhow::ensure!(
                r_root.is_none(),
                "state restore: R root present for a one-sided Kronecker unit"
            );
            anyhow::ensure!(
                r_corr.is_none(),
                "state restore: R corrector present for a one-sided Kronecker unit"
            );
        }
        if !self.ekfac {
            anyhow::ensure!(
                l_corr.is_none() && r_corr.is_none(),
                "state restore: ekfac corrector state for a unit constructed without ekfac"
            );
        }
        // An ekfac unit accepts a corrector-free (pre-ekfac) payload: it
        // simply refreshes on its next preconditioned step.
        let l_corr = match l_corr {
            Some(s) => Some(EigCorr::restore("L corrector", s, m)?),
            None => None,
        };
        let r_corr = match r_corr {
            Some(s) => Some(EigCorr::restore("R corrector", s, n)?),
            None => None,
        };
        self.l = l;
        self.r = r;
        self.l_root = l_root;
        self.r_root = r_root;
        self.l_corr = l_corr;
        self.r_corr = r_corr;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FD-sketched factors (S-Shampoo).
// ---------------------------------------------------------------------------

/// One side (L or R) of the factored S-Shampoo preconditioner.
pub(crate) enum Side {
    /// dim ≤ ℓ: exact EMA factor, spectral root cached.
    Exact { c: Matrix, root: Option<Matrix>, corr: Option<EigCorr> },
    /// dim > ℓ: EW-FD sketch (Obs. 6), applied in factored form.
    Sketched { fd: FdSketch, corr: Option<SketchCorr> },
}

impl Side {
    pub(crate) fn new(dim: usize, rank: usize, beta2: f64) -> Side {
        if dim <= rank {
            Side::Exact { c: Matrix::zeros(dim, dim), root: None, corr: None }
        } else {
            Side::Sketched { fd: FdSketch::new(dim, rank, beta2), corr: None }
        }
    }

    /// Update statistics with news factor Y (news = Y Yᵀ). With ekfac on,
    /// a sketched side reseeds its corrector here: the FD shrink *is*
    /// this side's eigendecomposition, so the corrected diagonal restarts
    /// from the fresh eigenvalues + escaped mass.
    pub(crate) fn update(&mut self, y: &Matrix, beta2: f64, ekfac: bool) {
        match self {
            Side::Exact { c, .. } => {
                c.scale_inplace(beta2);
                c.axpy(1.0, &a_at(y));
            }
            Side::Sketched { fd, corr } => {
                fd.update(y);
                if ekfac {
                    *corr = Some(SketchCorr::reseed(fd));
                }
            }
        }
    }

    /// Refresh any cached spectral roots (exact mode only; sketched sides
    /// apply their inverse roots directly from the factored form, so they
    /// are never stale). With ekfac on, an exact side keeps the eigenbasis
    /// + corrected diagonal instead of a frozen root. Returns whether an
    /// eigendecomposition ran.
    pub(crate) fn refresh_root(&mut self, eps: f64, p: f64, ekfac: bool) -> bool {
        if let Side::Exact { c, root, corr } = self {
            if ekfac {
                *corr = Some(EigCorr::reseed(c));
            } else {
                *root = Some(inv_pth_root(c, p, eps));
            }
            true
        } else {
            false
        }
    }

    pub(crate) fn has_root(&self, ekfac: bool) -> bool {
        match self {
            Side::Exact { root, corr, .. } => {
                if ekfac {
                    corr.is_some()
                } else {
                    root.is_some()
                }
            }
            Side::Sketched { .. } => true,
        }
    }

    /// EKFAC per-step correction: fold `g`'s row-space second moments in
    /// this side's stale basis (the L factor sees `GGᵀ`).
    pub(crate) fn track_left(&mut self, g: &Matrix, beta2: f64) {
        match self {
            Side::Exact { corr: Some(c), .. } => c.track_left(g, beta2),
            Side::Sketched { fd, corr: Some(c) } => c.track_left(fd.basis(), g, beta2),
            _ => {}
        }
    }

    /// Column-space mirror (the R factor sees `GᵀG`).
    pub(crate) fn track_right(&mut self, g: &Matrix, beta2: f64) {
        match self {
            Side::Exact { corr: Some(c), .. } => c.track_right(g, beta2),
            Side::Sketched { fd, corr: Some(c) } => c.track_right(fd.basis(), g, beta2),
            _ => {}
        }
    }

    /// Apply this side's `(·)^{-1/p}` from the left: `C^{-1/p} X`
    /// (p = 4 two-sided Shampoo, p = 2 one-sided §3.4).
    pub(crate) fn apply_left(&self, x: &Matrix, eps: f64, p: f64, ekfac: bool) -> Matrix {
        match self {
            Side::Exact { root, corr, .. } => {
                if ekfac {
                    corr.as_ref().expect("refresh before apply").apply_left(x, eps, p)
                } else {
                    matmul(root.as_ref().expect("root not ready"), x)
                }
            }
            Side::Sketched { fd, corr } => {
                if ekfac {
                    // Before the first ingest there is nothing to correct;
                    // fall through to the (empty-sketch) legacy apply.
                    if let Some(c) = corr {
                        return c.apply_left(fd.basis(), x, eps, p);
                    }
                }
                // L̃ = Ḡ + (ρ_{1:t} + ε) I, per Alg. 3 line 6 plus the ε
                // ridge of the initialization L̃₀ = εI.
                let pre = fd.shifted(fd.escaped_mass() + eps);
                pre.apply_inv_root_left(p, x)
            }
        }
    }

    /// Apply this side's `(·)^{-1/4}` from the right: `X C^{-1/4}`.
    pub(crate) fn apply_right(&self, x: &Matrix, eps: f64, ekfac: bool) -> Matrix {
        match self {
            Side::Exact { root, corr, .. } => {
                if ekfac {
                    corr.as_ref().expect("refresh before apply").apply_right(x, eps, 4.0)
                } else {
                    matmul(x, root.as_ref().expect("root not ready"))
                }
            }
            Side::Sketched { fd, corr } => {
                if ekfac {
                    if let Some(c) = corr {
                        return c.apply_right(fd.basis(), x, eps, 4.0);
                    }
                }
                let pre = fd.shifted(fd.escaped_mass() + eps);
                pre.apply_inv_root_right(4.0, x)
            }
        }
    }

    pub(crate) fn mem_bytes(&self) -> usize {
        match self {
            Side::Exact { c, root, corr } => {
                c.mem_bytes()
                    + root.as_ref().map(|m| m.mem_bytes()).unwrap_or(0)
                    + corr.as_ref().map(|cr| cr.mem_bytes()).unwrap_or(0)
            }
            Side::Sketched { fd, corr } => {
                fd.mem_bytes() + corr.as_ref().map(|cr| cr.mem_bytes()).unwrap_or(0)
            }
        }
    }

    pub(crate) fn second_moment_bytes(&self) -> usize {
        match self {
            Side::Exact { c, .. } => c.mem_bytes(),
            Side::Sketched { fd, .. } => fd.mem_bytes(),
        }
    }

    /// Escaped mass (0 in exact mode) — diagnostics.
    pub(crate) fn escaped(&self) -> f64 {
        match self {
            Side::Exact { .. } => 0.0,
            Side::Sketched { fd, .. } => fd.escaped_mass(),
        }
    }

    /// Snapshot this side's mutable state in its natural factored form.
    pub(crate) fn snapshot(&self) -> SideState {
        match self {
            Side::Exact { c, root, corr } => SideState::Exact {
                c: c.clone(),
                root: root.clone(),
                corr: corr.as_ref().map(|cr| cr.snapshot()),
            },
            Side::Sketched { fd, corr } => SideState::Sketch {
                sketch: SketchState {
                    basis: fd.basis().clone(),
                    eigvals: fd.eigenvalues().to_vec(),
                    escaped_mass: fd.escaped_mass(),
                    last_rho: fd.last_escaped(),
                    steps: fd.steps() as u64,
                },
                corr: corr.as_ref().map(|cr| cr.snapshot()),
            },
        }
    }

    /// Restore a [`Side::snapshot`]; the side mode (exact vs sketched)
    /// and every dimension must match this side's construction. Corrector
    /// state is refused unless the owning unit runs with ekfac on.
    pub(crate) fn restore(&mut self, state: SideState, ekfac: bool) -> anyhow::Result<()> {
        match (self, state) {
            (
                Side::Exact { c, root, corr },
                SideState::Exact { c: nc, root: nroot, corr: ncorr },
            ) => {
                let d = c.rows();
                ensure_shape("exact side factor", &nc, d, d)?;
                ensure_opt_shape("exact side root", &nroot, d, d)?;
                anyhow::ensure!(
                    ekfac || ncorr.is_none(),
                    "state restore: ekfac corrector state for a side constructed without ekfac"
                );
                let ncorr = match ncorr {
                    Some(cs) => Some(EigCorr::restore("exact side corrector", cs, d)?),
                    None => None,
                };
                *c = nc;
                *root = nroot;
                *corr = ncorr;
            }
            (Side::Sketched { fd, corr }, SideState::Sketch { sketch: s, corr: ncorr }) => {
                anyhow::ensure!(
                    s.basis.rows() == fd.dim() && s.basis.cols() == fd.rank(),
                    "state restore: sketch basis {}x{} != expected {}x{}",
                    s.basis.rows(),
                    s.basis.cols(),
                    fd.dim(),
                    fd.rank()
                );
                anyhow::ensure!(
                    ekfac || ncorr.is_none(),
                    "state restore: ekfac corrector state for a side constructed without ekfac"
                );
                let ncorr = match ncorr {
                    Some(cs) => Some(SketchCorr::restore(cs, fd.rank())?),
                    None => None,
                };
                *fd = FdSketch::from_parts(
                    s.basis,
                    s.eigvals,
                    fd.decay(),
                    s.escaped_mass,
                    s.last_rho,
                    s.steps as usize,
                )?;
                *corr = ncorr;
            }
            (Side::Exact { .. }, SideState::Sketch { .. }) => {
                anyhow::bail!("state restore: sketch payload for an exact side")
            }
            (Side::Sketched { .. }, SideState::Exact { .. }) => {
                anyhow::bail!("state restore: exact payload for a sketched side")
            }
        }
        Ok(())
    }
}

/// Sketched S-Shampoo unit: an FD sketch (or exact small factor) per side.
pub struct SketchUnit {
    pub(crate) left: Side,
    pub(crate) right: Side,
    beta2: f64,
    eps: f64,
    one_sided: bool,
    ekfac: bool,
}

impl SketchUnit {
    pub fn new(shape: (usize, usize), rank: usize, beta2: f64, eps: f64, one_sided: bool) -> Self {
        let (m, n) = shape;
        SketchUnit {
            left: Side::new(m, rank, beta2),
            right: Side::new(n, rank, beta2),
            beta2,
            eps,
            one_sided,
            ekfac: false,
        }
    }

    /// Enable the EKFAC-style inter-refresh corrector (builder style;
    /// resolved once at engine construction, never toggled mid-run).
    pub fn ekfac(mut self, on: bool) -> Self {
        self.ekfac = on;
        self
    }

    fn left_p(&self) -> f64 {
        if self.one_sided {
            2.0
        } else {
            4.0
        }
    }

    /// Cumulative escaped mass (left, right) — E3/E9 diagnostics.
    pub fn escaped(&self) -> (f64, f64) {
        (self.left.escaped(), self.right.escaped())
    }
}

impl Preconditioner for SketchUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.left.update(g, self.beta2, self.ekfac);
        if !self.one_sided {
            self.right.update(&g.t(), self.beta2, self.ekfac);
        }
    }

    fn refresh(&mut self) -> bool {
        let mut did = self.left.refresh_root(self.eps, self.left_p(), self.ekfac);
        if !self.one_sided {
            did |= self.right.refresh_root(self.eps, 4.0, self.ekfac);
        }
        did
    }

    fn ready(&self) -> bool {
        self.left.has_root(self.ekfac) && (self.one_sided || self.right.has_root(self.ekfac))
    }

    fn track(&mut self, g: &Matrix) {
        if !self.ekfac {
            return;
        }
        self.left.track_left(g, self.beta2);
        if !self.one_sided {
            self.right.track_right(g, self.beta2);
        }
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        // L̃^{-1/4} G R̃^{-1/4} in factored form, O(mnℓ)
        // (one-sided: L̃^{-1/2} G).
        let half = self.left.apply_left(g, self.eps, self.left_p(), self.ekfac);
        if self.one_sided {
            half
        } else {
            self.right.apply_right(&half, self.eps, self.ekfac)
        }
    }

    fn mem_bytes(&self) -> usize {
        self.left.mem_bytes() + self.right.mem_bytes()
    }

    fn second_moment_bytes(&self) -> usize {
        self.left.second_moment_bytes() + self.right.second_moment_bytes()
    }

    fn sketches(&self) -> Vec<&FdSketch> {
        let mut out = vec![];
        if let Side::Sketched { fd, .. } = &self.left {
            out.push(fd);
        }
        if let Side::Sketched { fd, .. } = &self.right {
            out.push(fd);
        }
        out
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Sketch { left: self.left.snapshot(), right: self.right.snapshot() }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Sketch { left, right } = state else {
            anyhow::bail!("state restore: non-sketch payload for a sketch unit");
        };
        self.left.restore(left, self.ekfac)?;
        self.right.restore(right, self.ekfac)
    }
}

// ---------------------------------------------------------------------------
// Diagonal (Adam) unit.
// ---------------------------------------------------------------------------

/// Diagonal Adam unit: first/second-moment EMAs with bias correction.
///
/// `apply` returns the full Adam direction `m̂/(√v̂ + ε)`; driven with
/// grafting off and driver momentum β₁ = 0, the engine step reproduces
/// the fused [`Adam`](super::Adam) bitwise (blocking included — the
/// update is elementwise).
pub struct AdamUnit {
    beta1: f64,
    beta2: f64,
    eps: f64,
    m: Matrix,
    v: Matrix,
    t: usize,
}

impl AdamUnit {
    pub fn new(shape: (usize, usize), beta1: f64, beta2: f64, eps: f64) -> Self {
        let (r, c) = shape;
        AdamUnit { beta1, beta2, eps, m: Matrix::zeros(r, c), v: Matrix::zeros(r, c), t: 0 }
    }
}

impl Preconditioner for AdamUnit {
    fn ingest(&mut self, g: &Matrix) {
        self.t += 1;
        let ms = self.m.as_mut_slice();
        let vs = self.v.as_mut_slice();
        let gs = g.as_slice();
        for j in 0..gs.len() {
            ms[j] = self.beta1 * ms[j] + (1.0 - self.beta1) * gs[j];
            vs[j] = self.beta2 * vs[j] + (1.0 - self.beta2) * gs[j] * gs[j];
        }
    }

    fn refresh(&mut self) -> bool {
        false
    }

    fn ready(&self) -> bool {
        true
    }

    fn apply(&self, g: &Matrix) -> Matrix {
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let mut out = Matrix::zeros(g.rows(), g.cols());
        let os = out.as_mut_slice();
        let ms = self.m.as_slice();
        let vs = self.v.as_slice();
        for j in 0..os.len() {
            let mhat = ms[j] / bc1;
            let vhat = vs[j] / bc2;
            os[j] = mhat / (vhat.sqrt() + self.eps);
        }
        out
    }

    fn mem_bytes(&self) -> usize {
        self.m.mem_bytes() + self.v.mem_bytes()
    }

    fn second_moment_bytes(&self) -> usize {
        self.v.mem_bytes()
    }

    fn state_payload(&self) -> PrecondState {
        PrecondState::Diag { m: self.m.clone(), v: self.v.clone(), t: self.t as u64 }
    }

    fn restore_payload(&mut self, state: PrecondState) -> anyhow::Result<()> {
        let PrecondState::Diag { m, v, t } = state else {
            anyhow::bail!("state restore: non-diagonal payload for an Adam unit");
        };
        let (r, c) = (self.m.rows(), self.m.cols());
        ensure_shape("Adam first moment", &m, r, c)?;
        ensure_shape("Adam second moment", &v, r, c)?;
        self.m = m;
        self.v = v;
        self.t = t as usize;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared per-block step driver.
// ---------------------------------------------------------------------------

/// Per-block optimizer state driven by the engine: a preconditioner unit
/// plus the first-order companions (grafting, momentum).
pub struct BlockState {
    pub unit: Box<dyn Preconditioner>,
    pub graft: Graft,
    pub mu: Matrix,
    /// Scratch gathered parameter block (engine-owned copy).
    pub(crate) param: Matrix,
    /// Scratch gathered gradient block.
    pub(crate) grad: Matrix,
}

impl BlockState {
    pub fn new(
        unit: Box<dyn Preconditioner>,
        graft: GraftType,
        shape: (usize, usize),
        beta2: f64,
    ) -> Self {
        let (r, c) = shape;
        BlockState {
            unit,
            graft: Graft::new(graft, (r, c), beta2),
            mu: Matrix::zeros(r, c),
            param: Matrix::zeros(r, c),
            grad: Matrix::zeros(r, c),
        }
    }

    /// Total heap bytes of this block's optimizer state (unit + graft +
    /// momentum + gathered scratch) — the one accounting formula shared
    /// by the in-process executor and the shard workers.
    pub fn mem_bytes(&self) -> usize {
        self.unit.mem_bytes()
            + self.graft.mem_bytes()
            + self.mu.mem_bytes()
            + self.param.mem_bytes()
            + self.grad.mem_bytes()
    }

    /// Bytes of second-moment (covariance) state only.
    pub fn second_moment_bytes(&self) -> usize {
        self.unit.second_moment_bytes()
    }

    /// Snapshot the block's full mutable optimizer state: the unit's
    /// typed payload plus the first-order companions (momentum, grafting
    /// accumulator). Scratch buffers never travel.
    pub fn snapshot(&self) -> BlockStateSnap {
        let (graft_v, graft_t) = self.graft.snapshot();
        BlockStateSnap { unit: self.unit.state_payload(), mu: self.mu.clone(), graft_v, graft_t }
    }

    /// Restore a [`BlockState::snapshot`]; every shape/kind must match
    /// this block's construction. On success the block steps bitwise
    /// identically to the snapshotted one. A failed restore may leave
    /// the block partially updated — callers treat `Err` as fatal.
    pub fn restore(&mut self, snap: BlockStateSnap) -> anyhow::Result<()> {
        ensure_shape("momentum", &snap.mu, self.mu.rows(), self.mu.cols())?;
        self.unit.restore_payload(snap.unit)?;
        self.graft.restore(snap.graft_v, snap.graft_t)?;
        self.mu = snap.mu;
        Ok(())
    }
}

/// Full serialized optimizer state of one block: the preconditioner
/// unit's [`PrecondState`] plus momentum and grafting companions. This is
/// what crosses the [`crate::optim::engine::BlockExecutor`] state
/// boundary and lands in v2 checkpoints.
#[derive(Clone, Debug)]
pub struct BlockStateSnap {
    pub unit: PrecondState,
    pub mu: Matrix,
    pub graft_v: Option<Matrix>,
    pub graft_t: u64,
}

/// Parameters controlling one driven step (shared by all blocks).
///
/// Public because it crosses the [`crate::optim::engine::BlockExecutor`]
/// boundary: the engine computes one `StepCtx` per block (including the
/// block's staggered `refresh_due` slot) and executors — in-process or
/// cross-process — drive [`drive_block`]-equivalent logic from it.
#[derive(Clone, Copy, Debug)]
pub struct StepCtx {
    pub t: usize,
    pub scale: f64,
    pub preconditioning: bool,
    pub refresh_due: bool,
    pub lr: f64,
    pub beta1: f64,
    pub weight_decay: f64,
    pub stat_due: bool,
    pub graft: GraftType,
}

/// One block step: the exact Shampoo/App. C flow — statistics, (possibly
/// stale) root refresh, graft, precondition, transplant, momentum,
/// decoupled weight decay. Returns `true` when an eigendecomposition ran
/// (the engine counts refreshes for its amortization accounting).
///
/// Allocation-discipline: the unclipped path borrows the gathered
/// gradient in place, and `GraftType::None` (whose graft "step" is a
/// full clone of the gradient) skips the graft companion entirely.
pub(crate) fn drive_block(st: &mut BlockState, ctx: &StepCtx) -> bool {
    let BlockState { unit, graft, mu, param, grad } = st;
    let scaled;
    let g: &Matrix = if ctx.scale != 1.0 {
        scaled = grad.scale(ctx.scale);
        &scaled
    } else {
        grad
    };
    if ctx.stat_due {
        unit.ingest(g);
    }
    let mut refreshed = false;
    if ctx.preconditioning && (!unit.ready() || ctx.refresh_due) {
        refreshed = unit.refresh();
    }
    // EKFAC correction folds this step's gradient moments into the stale
    // eigenbasis (no-op for non-ekfac units). Placed after any refresh
    // and before the apply so the corrector mutation order is identical
    // under the synchronous and RefreshAhead-overlapped schedules.
    if ctx.preconditioning {
        unit.track(g);
    }
    let update = if ctx.preconditioning {
        let dir = unit.apply(g);
        if ctx.graft == GraftType::None {
            dir
        } else {
            transplant(&graft.step(g), &dir)
        }
    } else {
        graft.step(g)
    };
    mu.scale_inplace(ctx.beta1);
    mu.axpy(1.0 - ctx.beta1, &update);
    let ps = param.as_mut_slice();
    let ms = mu.as_slice();
    for j in 0..ps.len() {
        ps[j] -= ctx.lr * (ms[j] + ctx.weight_decay * ps[j]);
    }
    refreshed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn kronecker_unit_whitens_after_refresh() {
        let mut rng = Pcg64::new(200);
        let mut unit = KroneckerUnit::new((6, 4), 1.0, 1e-9, false);
        let g = Matrix::randn(6, 4, &mut rng);
        assert!(!unit.ready());
        unit.ingest(&g);
        unit.refresh();
        assert!(unit.ready());
        // L^{-1/4} G R^{-1/4} with L = GGᵀ, R = GᵀG has unit-scale spectrum:
        // for G = UΣVᵀ the preconditioned direction is UVᵀ (+ eps ridge).
        let dir = unit.apply(&g);
        let gram = crate::tensor::at_a(&dir);
        for i in 0..4 {
            assert!((gram[(i, i)] - 1.0).abs() < 1e-3, "diag {}", gram[(i, i)]);
        }
    }

    #[test]
    fn kronecker_one_sided_skips_right() {
        let mut rng = Pcg64::new(201);
        let mut unit = KroneckerUnit::new((5, 3), 0.999, 1e-6, true);
        unit.ingest(&Matrix::randn(5, 3, &mut rng));
        unit.refresh();
        assert!(unit.ready());
        assert_eq!(unit.r.fro_norm(), 0.0);
        assert!(unit.r_root.is_none());
    }

    #[test]
    fn sketch_unit_exposes_fd_sketches() {
        // 10×2 with rank 4: left side is sketched (10 > 4), right exact.
        let mut unit = SketchUnit::new((10, 2), 4, 0.999, 1e-6, false);
        assert_eq!(unit.sketches().len(), 1);
        let mut rng = Pcg64::new(202);
        unit.ingest(&Matrix::randn(10, 2, &mut rng));
        assert!(unit.sketches()[0].steps() > 0);
    }

    /// Drive two identical blocks a few steps, snapshot/restore one into
    /// a fresh block, then keep driving both and demand bitwise equality.
    fn assert_snapshot_restore_is_bitwise(mk: impl Fn() -> BlockState, shape: (usize, usize)) {
        let mut rng = Pcg64::new(205);
        let mut a = mk();
        let ctx = StepCtx {
            t: 0,
            scale: 1.0,
            preconditioning: true,
            refresh_due: true,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 0.001,
            stat_due: true,
            graft: GraftType::Rmsprop,
        };
        for t in 1..=5 {
            a.grad = Matrix::randn(shape.0, shape.1, &mut rng);
            drive_block(&mut a, &StepCtx { t, refresh_due: t % 2 == 0, ..ctx });
        }
        let mut b = mk();
        b.restore(a.snapshot()).unwrap();
        b.param = a.param.clone();
        assert_eq!(a.mem_bytes(), b.mem_bytes());
        for t in 6..=10 {
            let g = Matrix::randn(shape.0, shape.1, &mut rng);
            a.grad = g.clone();
            b.grad = g;
            let c = StepCtx { t, refresh_due: t % 2 == 0, ..ctx };
            drive_block(&mut a, &c);
            drive_block(&mut b, &c);
            assert_eq!(a.param.max_diff(&b.param), 0.0, "diverged at t={t}");
            assert_eq!(a.mu.max_diff(&b.mu), 0.0);
        }
    }

    #[test]
    fn kronecker_state_roundtrips_bitwise() {
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(KroneckerUnit::new((6, 4), 0.999, 1e-9, false)),
                    GraftType::Rmsprop,
                    (6, 4),
                    0.999,
                )
            },
            (6, 4),
        );
    }

    #[test]
    fn sketch_state_roundtrips_bitwise() {
        // 10×3 at rank 4: left sketched, right exact — both side modes.
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(SketchUnit::new((10, 3), 4, 0.999, 1e-9, false)),
                    GraftType::Rmsprop,
                    (10, 3),
                    0.999,
                )
            },
            (10, 3),
        );
    }

    #[test]
    fn adam_state_roundtrips_bitwise() {
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(AdamUnit::new((5, 5), 0.9, 0.999, 1e-8)),
                    GraftType::Rmsprop,
                    (5, 5),
                    0.999,
                )
            },
            (5, 5),
        );
    }

    #[test]
    fn state_restore_rejects_mismatched_payloads() {
        // Wrong kind.
        let mut kron = KroneckerUnit::new((4, 4), 0.999, 1e-9, false);
        let adam = AdamUnit::new((4, 4), 0.9, 0.999, 1e-8);
        assert!(kron.restore_payload(adam.state_payload()).is_err());
        // Wrong shape.
        let other = KroneckerUnit::new((5, 4), 0.999, 1e-9, false);
        assert!(kron.restore_payload(other.state_payload()).is_err());
        // One-sided unit refuses a right root.
        let mut one_sided = KroneckerUnit::new((4, 4), 0.999, 1e-9, true);
        let mut two_sided = KroneckerUnit::new((4, 4), 0.999, 1e-9, false);
        let mut rng = Pcg64::new(206);
        two_sided.ingest(&Matrix::randn(4, 4, &mut rng));
        two_sided.refresh();
        assert!(one_sided.restore_payload(two_sided.state_payload()).is_err());
        // Sketched/exact side mode mismatch (rank 4: dim 10 sketched,
        // dim 3 exact — transposed unit flips the modes).
        let mut unit = SketchUnit::new((10, 3), 4, 0.999, 1e-9, false);
        let flipped = SketchUnit::new((3, 10), 4, 0.999, 1e-9, false);
        assert!(unit.restore_payload(flipped.state_payload()).is_err());
        // Adversarial sketch rank: basis with the wrong column count.
        let PrecondState::Sketch { left, right } = unit.state_payload() else { unreachable!() };
        let SideState::Sketch { sketch: mut s, corr } = left else { unreachable!() };
        s.basis = Matrix::zeros(10, 7);
        s.eigvals = vec![0.0; 7];
        assert!(unit
            .restore_payload(PrecondState::Sketch {
                left: SideState::Sketch { sketch: s, corr },
                right,
            })
            .is_err());
        // A non-ekfac unit refuses ekfac corrector state...
        let mut plain = KroneckerUnit::new((4, 4), 0.999, 1e-9, false);
        let mut ek = KroneckerUnit::new((4, 4), 0.999, 1e-9, false).ekfac(true);
        ek.ingest(&Matrix::randn(4, 4, &mut rng));
        ek.refresh();
        assert!(plain.restore_payload(ek.state_payload()).is_err());
        // ...an ekfac unit accepts a corrector-free (pre-ekfac) payload,
        // degrading to a refresh on its next preconditioned step...
        let mut ek2 = KroneckerUnit::new((4, 4), 0.999, 1e-9, false).ekfac(true);
        assert!(ek2.restore_payload(plain.state_payload()).is_ok());
        assert!(!ek2.ready());
        // ...and the sketched family enforces the same refusal.
        let mut plain_sk = SketchUnit::new((10, 3), 4, 0.999, 1e-9, false);
        let mut ek_sk = SketchUnit::new((10, 3), 4, 0.999, 1e-9, false).ekfac(true);
        ek_sk.ingest(&Matrix::randn(10, 3, &mut rng));
        ek_sk.refresh();
        assert!(plain_sk.restore_payload(ek_sk.state_payload()).is_err());
        // Graft companion shape mismatch surfaces through BlockState.
        let mk = || {
            BlockState::new(
                Box::new(AdamUnit::new((3, 3), 0.9, 0.999, 1e-8)),
                GraftType::Rmsprop,
                (3, 3),
                0.999,
            )
        };
        let mut blk = mk();
        let mut snap = mk().snapshot();
        snap.graft_v = Some(Matrix::zeros(2, 2));
        assert!(blk.restore(snap).is_err());
        let mut snap = mk().snapshot();
        snap.mu = Matrix::zeros(9, 1);
        assert!(blk.restore(snap).is_err());
    }

    #[test]
    fn kronecker_ekfac_state_roundtrips_bitwise() {
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(KroneckerUnit::new((6, 4), 0.999, 1e-9, false).ekfac(true)),
                    GraftType::Rmsprop,
                    (6, 4),
                    0.999,
                )
            },
            (6, 4),
        );
    }

    #[test]
    fn sketch_ekfac_state_roundtrips_bitwise() {
        // 10×3 at rank 4: left sketched, right exact — both corrector
        // kinds cross the snapshot.
        assert_snapshot_restore_is_bitwise(
            || {
                BlockState::new(
                    Box::new(SketchUnit::new((10, 3), 4, 0.999, 1e-9, false).ekfac(true)),
                    GraftType::Rmsprop,
                    (10, 3),
                    0.999,
                )
            },
            (10, 3),
        );
    }

    #[test]
    fn ekfac_apply_matches_frozen_root_at_refresh() {
        // Right after a refresh the corrected diagonal equals the factor's
        // eigenvalues, so the EKFAC apply must reproduce the frozen-root
        // direction (numerically: different multiply association order).
        let mut rng = Pcg64::new(207);
        let g = Matrix::randn(6, 4, &mut rng);
        let mut frozen = KroneckerUnit::new((6, 4), 0.999, 1e-6, false);
        let mut corrected = KroneckerUnit::new((6, 4), 0.999, 1e-6, false).ekfac(true);
        frozen.ingest(&g);
        corrected.ingest(&g);
        frozen.refresh();
        corrected.refresh();
        assert!(corrected.ready());
        let a = frozen.apply(&g);
        let b = corrected.apply(&g);
        assert!(a.max_diff(&b) < 1e-8, "diff {}", a.max_diff(&b));
    }

    #[test]
    fn sketch_ekfac_apply_matches_factored_apply_at_reseed() {
        // A sketched side reseeds its corrector at every FD shrink, so
        // immediately after ingest+refresh the corrected apply must match
        // the legacy factored apply (eigenvalues + escaped-mass shift).
        let mut rng = Pcg64::new(209);
        let mut legacy = SketchUnit::new((12, 3), 4, 0.999, 1e-6, false);
        let mut ek = SketchUnit::new((12, 3), 4, 0.999, 1e-6, false).ekfac(true);
        for _ in 0..3 {
            let g = Matrix::randn(12, 3, &mut rng);
            legacy.ingest(&g);
            ek.ingest(&g);
        }
        legacy.refresh();
        ek.refresh();
        let g = Matrix::randn(12, 3, &mut rng);
        let a = legacy.apply(&g);
        let b = ek.apply(&g);
        assert!(a.max_diff(&b) < 1e-8, "diff {}", a.max_diff(&b));
    }

    #[test]
    fn ekfac_tracks_curvature_between_refreshes() {
        // After tracking a new gradient with no refresh in between, the
        // corrected apply must differ from the frozen one — the corrector
        // actually folds fresh curvature into the stale basis.
        let mut rng = Pcg64::new(208);
        let mut unit = KroneckerUnit::new((6, 4), 0.999, 1e-6, false).ekfac(true);
        let g1 = Matrix::randn(6, 4, &mut rng);
        unit.ingest(&g1);
        unit.refresh();
        let before = unit.apply(&g1);
        let g2 = Matrix::randn(6, 4, &mut rng);
        unit.track(&g2);
        let after = unit.apply(&g1);
        assert!(before.max_diff(&after) > 0.0);
    }

    #[test]
    fn adam_unit_matches_closed_form_first_step() {
        let mut unit = AdamUnit::new((1, 1), 0.9, 0.999, 1e-8);
        let g = Matrix::from_rows(&[vec![1234.5]]);
        unit.ingest(&g);
        let dir = unit.apply(&g);
        // Bias correction ⇒ first direction magnitude ≈ 1 for any g scale.
        assert!((dir[(0, 0)].abs() - 1.0).abs() < 1e-6);
    }
}
