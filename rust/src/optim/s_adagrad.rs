//! Sketchy AdaGrad — Algorithm 2 of the paper.
//!
//! Per round: (1) FD-update the sketch with `g gᵀ`; (2) form the
//! compensated preconditioner `G̃_t = Ḡ_t + ρ_{1:t} I` (never materialized
//! — applied through the factored identity in `sketch::factored`);
//! (3) descend `x ← x − η G̃_t^{-1/2} g`; (4) project in ‖·‖_{G̃^{1/2}} when
//! the domain is bounded. Memory: O(dℓ); per-round time O(dℓ² + ℓ³).
//!
//! Theorem 3 / Corollary 4 give the regret bound
//! `D(√2 tr G_T^{1/2} + √(d(d−ℓ)Ω_ℓ/2))` — full-matrix AdaGrad regret up
//! to additive error in the bottom eigenvalues. E1 exercises this bound.

use super::vector::VectorOptimizer;
use crate::sketch::FdSketch;

/// Sketchy AdaGrad (Alg. 2).
pub struct SAdaGrad {
    pub lr: f64,
    sketch: FdSketch,
    t: usize,
}

impl SAdaGrad {
    /// `ell` is the sketch size ℓ (the paper's single new hyperparameter).
    pub fn new(d: usize, ell: usize, lr: f64) -> Self {
        SAdaGrad { lr, sketch: FdSketch::new(d, ell, 1.0), t: 0 }
    }

    /// Access the sketch (spectral diagnostics in E1/E7).
    pub fn sketch(&self) -> &FdSketch {
        &self.sketch
    }
}

impl VectorOptimizer for SAdaGrad {
    fn name(&self) -> String {
        "S-AdaGrad".into()
    }

    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>) {
        self.t += 1;
        // (1) Sketch (ρ_t, Ḡ_t) = FD-update(Ḡ_{t-1}, g gᵀ).
        self.sketch.update_vec(g);
        // (2)+(3) y = x − η G̃^{-1/2} g with G̃ = Ḡ + ρ_{1:t} I.
        let pre = self.sketch.compensated();
        let dir = pre.apply_inv_root_vec(2.0, g);
        for i in 0..x.len() {
            x[i] -= self.lr * dir[i];
        }
        // (4) Projection in the ‖·‖_{G̃^{1/2}} norm.
        if let Some(r) = radius {
            let projected = pre.project_ball(x, r);
            x.copy_from_slice(&projected);
        }
    }

    fn mem_bytes(&self) -> usize {
        self.sketch.mem_bytes()
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::full_matrix::AdaGradFull;
    use crate::tensor::random_orthonormal;
    use crate::util::rng::Pcg64;

    #[test]
    fn converges_on_quadratic() {
        let mut opt = SAdaGrad::new(4, 3, 0.5);
        let a = [1.0, -2.0, 0.5, 0.0];
        let mut x = [0.0; 4];
        for _ in 0..3000 {
            let g: Vec<f64> = (0..4).map(|i| x[i] - a[i]).collect();
            opt.step(&mut x, &g, None);
        }
        for i in 0..4 {
            assert!((x[i] - a[i]).abs() < 0.05, "x={x:?}");
        }
    }

    #[test]
    fn matches_full_adagrad_when_stream_is_low_rank() {
        // Gradients confined to a rank-(ℓ−1) subspace: the sketch is exact
        // (ρ = 0), so S-AdaGrad must track full-matrix AdaGrad (with
        // pseudo-inverse) exactly — the §3.3 observation.
        let mut rng = Pcg64::new(110);
        let d = 10;
        let ell = 4;
        let dirs = random_orthonormal(d, ell - 1, &mut rng);
        let mut skc = SAdaGrad::new(d, ell, 0.3);
        let mut full = AdaGradFull::new(d, 0.3);
        let mut xs = vec![0.0; d];
        let mut xf = vec![0.0; d];
        for _ in 0..40 {
            let c: Vec<f64> = (0..ell - 1).map(|_| rng.gaussian()).collect();
            let g: Vec<f64> = (0..d)
                .map(|i| (0..ell - 1).map(|j| dirs[(i, j)] * c[j]).sum())
                .collect();
            skc.step(&mut xs, &g, None);
            full.step(&mut xf, &g, None);
        }
        assert!(skc.sketch().escaped_mass() < 1e-9);
        for i in 0..d {
            assert!(
                (xs[i] - xf[i]).abs() < 1e-6,
                "low-rank equivalence broken: {xs:?} vs {xf:?}"
            );
        }
    }

    #[test]
    fn preconditioner_upper_bounds_covariance() {
        // Lemma 10 / Remark 11 on the live optimizer: G ⪯ G̃ at every step.
        let mut rng = Pcg64::new(111);
        let d = 6;
        let mut opt = SAdaGrad::new(d, 3, 0.1);
        let mut x = vec![0.0; d];
        let mut cov = crate::tensor::Matrix::zeros(d, d);
        for _ in 0..30 {
            let g = rng.gaussian_vec(d);
            cov = cov.add(&crate::tensor::outer(&g, &g));
            opt.step(&mut x, &g, None);
            let mut tilde = opt.sketch().materialize();
            tilde.add_diag(opt.sketch().escaped_mass());
            let gap = crate::tensor::eigh(&tilde.sub(&cov));
            assert!(
                gap.w.iter().all(|&v| v > -1e-7),
                "G ⋠ G̃, min gap eig {:?}",
                gap.w.last()
            );
        }
    }

    #[test]
    fn projection_keeps_feasible() {
        let mut rng = Pcg64::new(112);
        let mut opt = SAdaGrad::new(5, 3, 5.0);
        let mut x = vec![0.0; 5];
        for _ in 0..20 {
            let g = rng.gaussian_vec(5);
            opt.step(&mut x, &g, Some(1.0));
            assert!(crate::tensor::norm2(&x) <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn memory_is_d_ell_not_d_squared() {
        let d = 512;
        let opt = SAdaGrad::new(d, 8, 0.1);
        // d·(ℓ)·8 bytes plus change; far below d²·8.
        assert!(opt.mem_bytes() < d * 16 * 8);
        assert!(opt.mem_bytes() >= d * 8 * 8);
    }
}
