//! Sketchy Shampoo — Algorithm 3 of the paper, with the practical §4.3/§6
//! modifications: exponentially-weighted FD sketches for both Kronecker
//! factors, escaped-mass compensation, grafting, momentum, and the
//! "harder setting" cadence where statistics and preconditioner updates
//! share the same interval.
//!
//! Memory per m×n tensor: O((m+n)·ℓ) for second moments versus Shampoo's
//! O(m²+n²) — sub-linear in the parameter count mn once ℓ ≪ min(m, n)
//! (the Fig. 1 story). Sides whose dimension is ≤ ℓ use exact EMA factors
//! (sketching cannot help there and the paper's ℓ=256 implies the same).

use super::adam::clip_scale;
use super::grafting::{transplant, Graft, GraftType};
use super::matrix_opt::Optimizer;
use super::precond::{Preconditioner, SketchUnit};
use super::shampoo::ShampooConfig;
use crate::tensor::Matrix;

/// Configuration: shared Shampoo hyperparameters plus the sketch rank ℓ
/// (the paper's single new hyperparameter, set to 256 in §5.1).
#[derive(Clone, Debug)]
pub struct SShampooConfig {
    pub base: ShampooConfig,
    /// FD sketch size ℓ.
    pub rank: usize,
}

impl Default for SShampooConfig {
    fn default() -> Self {
        SShampooConfig { base: ShampooConfig::default(), rank: 256 }
    }
}

struct SShampooTensorState {
    /// FD-sketched preconditioner unit (`Side` internals live in
    /// [`super::precond`], shared with the parallel block engine).
    unit: SketchUnit,
    graft: Graft,
    mu: Matrix,
}

/// Sketchy Shampoo (Alg. 3 + §4.3).
pub struct SShampoo {
    pub cfg: SShampooConfig,
    states: Vec<SShampooTensorState>,
    t: usize,
}

impl SShampoo {
    pub fn new(shapes: &[(usize, usize)], cfg: SShampooConfig) -> Self {
        let states = shapes
            .iter()
            .map(|&(m, n)| SShampooTensorState {
                unit: SketchUnit::new(
                    (m, n),
                    cfg.rank,
                    cfg.base.beta2,
                    cfg.base.eps,
                    cfg.base.one_sided,
                )
                .ekfac(cfg.base.ekfac),
                graft: Graft::new(cfg.base.graft, (m, n), cfg.base.beta2),
                mu: Matrix::zeros(m, n),
            })
            .collect();
        SShampoo { cfg, states, t: 0 }
    }

    /// Cumulative escaped mass per tensor (left, right) — E3/E9 diagnostics.
    pub fn escaped_mass(&self) -> Vec<(f64, f64)> {
        self.states.iter().map(|s| s.unit.escaped()).collect()
    }
}

impl Optimizer for SShampoo {
    fn name(&self) -> String {
        format!("S-Shampoo(l={})", self.cfg.rank)
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        self.t += 1;
        let t = self.t;
        let cfg = self.cfg.base.clone();
        let scale = clip_scale(grads, cfg.clip);
        let preconditioning = t >= cfg.start_preconditioning_step;
        for (i, (p, g_raw)) in params.iter_mut().zip(grads.iter()).enumerate() {
            let st = &mut self.states[i];
            let g = if scale != 1.0 { g_raw.scale(scale) } else { g_raw.clone() };
            // §6: S-Shampoo observes every stat_interval-th gradient and
            // updates its covariance (and thereby its inverse roots, which
            // are implicit in the factored form) at the same cadence.
            if t % cfg.stat_interval == 0 {
                st.unit.ingest(&g);
                if preconditioning && t % cfg.precond_interval == 0 {
                    st.unit.refresh();
                }
            }
            // Ensure exact-mode roots exist before first preconditioned use
            // (sketched sides are always "ready": their inverse roots come
            // straight from the factored form).
            if preconditioning && !st.unit.ready() {
                st.unit.refresh();
            }
            // EKFAC correction in the stale sketch basis (no-op with
            // ekfac off) — same position relative to refresh/apply as
            // the engine's drive_block.
            if preconditioning {
                st.unit.track(&g);
            }
            let graft_step = st.graft.step(&g);
            let update = if preconditioning {
                // L̃^{-1/4} G R̃^{-1/4} in factored form, O(mnℓ)
                // (one-sided: L̃^{-1/2} G).
                let dir = st.unit.apply(&g);
                if cfg.graft == GraftType::None {
                    dir
                } else {
                    transplant(&graft_step, &dir)
                }
            } else {
                graft_step
            };
            st.mu.scale_inplace(cfg.beta1);
            st.mu.axpy(1.0 - cfg.beta1, &update);
            let ps = p.as_mut_slice();
            let ms = st.mu.as_slice();
            for j in 0..ps.len() {
                ps[j] -= cfg.lr * (ms[j] + cfg.weight_decay * ps[j]);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| s.unit.mem_bytes() + s.graft.mem_bytes() + s.mu.mem_bytes())
            .sum()
    }

    fn second_moment_bytes(&self) -> usize {
        self.states.iter().map(|s| s.unit.second_moment_bytes()).sum()
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.base.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::shampoo::Shampoo;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn cfg(rank: usize) -> SShampooConfig {
        SShampooConfig {
            base: ShampooConfig {
                lr: 0.05,
                start_preconditioning_step: 2,
                graft: GraftType::Rmsprop,
                ..Default::default()
            },
            rank,
        }
    }

    #[test]
    fn converges_on_matrix_quadratic() {
        let mut rng = Pcg64::new(160);
        let target = Matrix::randn(6, 4, &mut rng);
        let mut params = vec![Matrix::zeros(6, 4)];
        let mut opt = SShampoo::new(&[(6, 4)], cfg(3));
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }

    #[test]
    fn exact_mode_matches_shampoo_exactly() {
        // rank ≥ both dims ⇒ S-Shampoo's sides are exact EMA factors and
        // every step must equal Shampoo's bit for bit.
        let shapes = [(5, 3), (4, 1)];
        let base = ShampooConfig {
            lr: 0.02,
            start_preconditioning_step: 3,
            stat_interval: 2,
            precond_interval: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut sh = Shampoo::new(&shapes, base.clone());
        let mut ssh = SShampoo::new(&shapes, SShampooConfig { base, rank: 16 });
        let mut rng = Pcg64::new(161);
        let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut p2 = p1.clone();
        for _ in 0..25 {
            let grads: Vec<Matrix> = shapes
                .iter()
                .map(|&(m, n)| Matrix::randn(m, n, &mut rng))
                .collect();
            sh.step(&mut p1, &grads);
            ssh.step(&mut p2, &grads);
            for (a, b) in p1.iter().zip(&p2) {
                assert!(
                    a.max_diff(b) < 1e-9,
                    "exact-mode S-Shampoo deviated from Shampoo by {}",
                    a.max_diff(b)
                );
            }
        }
    }

    #[test]
    fn sketched_mode_tracks_shampoo_on_low_rank_stream() {
        // Gradients with a fixed rank-2 structure: a rank-4 sketch loses
        // (almost) nothing, so S-Shampoo stays close to exact Shampoo.
        let m = 12;
        let n = 10;
        let mut rng = Pcg64::new(162);
        let u = Matrix::randn(m, 2, &mut rng);
        let v = Matrix::randn(n, 2, &mut rng);
        let base = ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        };
        let mut sh = Shampoo::new(&[(m, n)], base.clone());
        let mut ssh = SShampoo::new(&[(m, n)], SShampooConfig { base, rank: 6 });
        let mut p1 = vec![Matrix::zeros(m, n)];
        let mut p2 = vec![Matrix::zeros(m, n)];
        for _ in 0..40 {
            let c = Matrix::randn(2, 2, &mut rng);
            let g = matmul(&matmul(&u, &c), &v.t());
            sh.step(&mut p1, &[g.clone()]);
            ssh.step(&mut p2, &[g]);
        }
        let diff = p1[0].max_diff(&p2[0]);
        let scale = p1[0].max_abs().max(1e-9);
        assert!(
            diff / scale < 0.15,
            "sketched S-Shampoo diverged from Shampoo: rel diff {}",
            diff / scale
        );
    }

    #[test]
    fn sublinear_memory_vs_shampoo() {
        // 512×256 tensor, rank 16: S-Shampoo second moments ≈ (512+256)·16
        // floats vs Shampoo's 512² + 256².
        let shapes = [(512, 256)];
        let ssh = SShampoo::new(&shapes, cfg(16));
        let sh = Shampoo::new(&shapes, ShampooConfig::default());
        assert!(ssh.second_moment_bytes() < sh.second_moment_bytes() / 20);
        // And the asymptotic form matches (m+n)·ℓ doubles:
        assert!(ssh.second_moment_bytes() <= (512 + 256) * 17 * 8);
    }

    #[test]
    fn escaped_mass_grows_on_full_rank_stream() {
        let mut opt = SShampoo::new(&[(10, 8)], cfg(3));
        let mut rng = Pcg64::new(163);
        let mut params = vec![Matrix::zeros(10, 8)];
        for _ in 0..30 {
            let g = Matrix::randn(10, 8, &mut rng);
            opt.step(&mut params, &[g]);
        }
        let (l, r) = opt.escaped_mass()[0];
        assert!(l > 0.0 && r > 0.0, "escaped mass should be positive: {l}, {r}");
    }

    #[test]
    fn one_sided_converges_with_half_memory() {
        let mut c = cfg(4);
        c.base.one_sided = true;
        let mut rng = Pcg64::new(165);
        let target = Matrix::randn(12, 12, &mut rng);
        let mut params = vec![Matrix::zeros(12, 12)];
        let mut opt = SShampoo::new(&[(12, 12)], c.clone());
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
        // The right sketch exists but is never fed: escaped mass stays 0.
        let (_, r) = opt.escaped_mass()[0];
        assert_eq!(r, 0.0);
    }

    #[test]
    fn vector_parameters_supported() {
        // n×1 tensors (biases): right side is 1×1 exact; must not panic
        // and must converge.
        let mut rng = Pcg64::new(164);
        let target = Matrix::randn(7, 1, &mut rng);
        let mut params = vec![Matrix::zeros(7, 1)];
        let mut opt = SShampoo::new(&[(7, 1)], cfg(4));
        for _ in 0..2000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }
}
