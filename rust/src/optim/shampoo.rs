//! Shampoo (Gupta et al. [5], Anil et al. [9]) — the exact Kronecker-
//! factored preconditioner that Sketchy approximates.
//!
//! Per m×n tensor it maintains EMA factors `L ← β₂L + G Gᵀ` (m×m) and
//! `R ← β₂R + GᵀG` (n×n), preconditions `L^{-1/4} G R^{-1/4}`, grafts the
//! step magnitude from a diagonal method, and applies momentum — the
//! App. C production configuration: statistics observed every
//! `stat_interval` steps, inverse roots recomputed every
//! `precond_interval` steps, preconditioning starting at
//! `start_preconditioning_step`.

use super::adam::clip_scale;
use super::grafting::{transplant, Graft, GraftType};
use super::matrix_opt::Optimizer;
use super::precond::{KroneckerUnit, Preconditioner};
use crate::tensor::Matrix;

/// Hyperparameters shared by Shampoo and S-Shampoo.
#[derive(Clone, Debug)]
pub struct ShampooConfig {
    pub lr: f64,
    /// Momentum (β₁), applied as a moving average of updates.
    pub beta1: f64,
    /// Second-moment EMA decay (β₂).
    pub beta2: f64,
    /// Ridge added to factor spectra before the inverse root.
    pub eps: f64,
    /// Decoupled weight decay.
    pub weight_decay: f64,
    /// Global-norm gradient clip (0 disables).
    pub clip: f64,
    /// Use grafting updates only until this step (App. C: 101).
    pub start_preconditioning_step: usize,
    /// Observe covariance statistics every k-th step (App. C / §6: 10;
    /// S-Shampoo deliberately shares this "harder setting").
    pub stat_interval: usize,
    /// Recompute inverse roots every k-th step (App. C: 10).
    pub precond_interval: usize,
    /// Grafting method (App. C: RMSPROP_NORMALIZED).
    pub graft: GraftType,
    /// One-sided covariance bound (§3.4 workaround #2): precondition
    /// with `L^{-1/2} G` only, dropping the right factor entirely —
    /// halves memory for square tensors and avoids the large-side factor
    /// for rectangular ones.
    pub one_sided: bool,
    /// EKFAC-style inter-refresh corrections (George et al.): between
    /// eigendecompositions, fold each step's gradient second moments into
    /// a corrected diagonal in the stale eigenbasis and apply with those
    /// scales instead of the frozen eigenvalues — lets `precond_interval`
    /// (and the engine's refresh interval) stretch 4 → 32+ without
    /// quality loss. Resolved once at construction, never toggled mid-run.
    pub ekfac: bool,
}

impl Default for ShampooConfig {
    fn default() -> Self {
        ShampooConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.0,
            clip: 0.0,
            start_preconditioning_step: 10,
            stat_interval: 1,
            precond_interval: 1,
            graft: GraftType::RmspropNormalized,
            one_sided: false,
            ekfac: false,
        }
    }
}

struct ShampooTensorState {
    /// Exact-Kronecker preconditioner unit (the shared
    /// [`Preconditioner`] interface the parallel engine also drives).
    unit: KroneckerUnit,
    graft: Graft,
    mu: Matrix,
}

/// Exact Shampoo.
pub struct Shampoo {
    pub cfg: ShampooConfig,
    states: Vec<ShampooTensorState>,
    t: usize,
}

impl Shampoo {
    pub fn new(shapes: &[(usize, usize)], cfg: ShampooConfig) -> Self {
        let states = shapes
            .iter()
            .map(|&(m, n)| ShampooTensorState {
                unit: KroneckerUnit::new((m, n), cfg.beta2, cfg.eps, cfg.one_sided)
                    .ekfac(cfg.ekfac),
                graft: Graft::new(cfg.graft, (m, n), cfg.beta2),
                mu: Matrix::zeros(m, n),
            })
            .collect();
        Shampoo { cfg, states, t: 0 }
    }
}

impl Optimizer for Shampoo {
    fn name(&self) -> String {
        "Shampoo".into()
    }

    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        self.t += 1;
        let t = self.t;
        let cfg = &self.cfg;
        let scale = clip_scale(grads, cfg.clip);
        let preconditioning = t >= cfg.start_preconditioning_step;
        for (i, (p, g_raw)) in params.iter_mut().zip(grads.iter()).enumerate() {
            let st = &mut self.states[i];
            let g = if scale != 1.0 { g_raw.scale(scale) } else { g_raw.clone() };
            // Statistics every stat_interval steps.
            if t % cfg.stat_interval == 0 {
                st.unit.ingest(&g);
            }
            // Inverse roots every precond_interval steps (and on the first
            // preconditioned step). One-sided uses L^{-1/2} (the full
            // AdaGrad exponent on the single factor).
            if preconditioning && (!st.unit.ready() || t % cfg.precond_interval == 0) {
                st.unit.refresh();
            }
            // EKFAC correction in the stale basis (no-op with ekfac off) —
            // same position relative to refresh/apply as the engine's
            // drive_block, so fused ≡ engine holds with ekfac on too.
            if preconditioning {
                st.unit.track(&g);
            }
            let graft_step = st.graft.step(&g);
            let update = if preconditioning {
                let dir = st.unit.apply(&g);
                if cfg.graft == GraftType::None {
                    dir
                } else {
                    transplant(&graft_step, &dir)
                }
            } else {
                graft_step
            };
            // Momentum as a moving average of updates (App. C).
            st.mu.scale_inplace(cfg.beta1);
            st.mu.axpy(1.0 - cfg.beta1, &update);
            // Decoupled weight decay + descent.
            let ps = p.as_mut_slice();
            let ms = st.mu.as_slice();
            for j in 0..ps.len() {
                ps[j] -= cfg.lr * (ms[j] + cfg.weight_decay * ps[j]);
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        self.states
            .iter()
            .map(|s| s.unit.mem_bytes() + s.graft.mem_bytes() + s.mu.mem_bytes())
            .sum()
    }

    fn second_moment_bytes(&self) -> usize {
        self.states.iter().map(|s| s.unit.second_moment_bytes()).sum()
    }

    fn set_lr(&mut self, lr: f64) {
        self.cfg.lr = lr;
    }

    fn steps(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn default_cfg() -> ShampooConfig {
        ShampooConfig {
            lr: 0.05,
            start_preconditioning_step: 2,
            graft: GraftType::Rmsprop,
            ..Default::default()
        }
    }

    #[test]
    fn converges_on_matrix_quadratic() {
        let shapes = [(4, 3)];
        let mut rng = Pcg64::new(150);
        let target = Matrix::randn(4, 3, &mut rng);
        let mut params = vec![Matrix::zeros(4, 3)];
        let mut opt = Shampoo::new(&shapes, default_cfg());
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
    }

    #[test]
    fn preconditioner_whitens_repeated_gradient() {
        // With β₂ = 1 (pure sum) and the same rank-1 gradient every step,
        // L ≈ t·uuᵀ‖v‖² and R ≈ t·vvᵀ‖u‖², so the un-grafted direction
        // L^{-1/4} G R^{-1/4} decays like t^{-1/2} — AdaGrad-style
        // whitening, the mechanism behind the paper's regret bounds.
        let mut rng = Pcg64::new(151);
        let u: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
        let v: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
        let mut cfg = default_cfg();
        cfg.graft = GraftType::None;
        cfg.beta1 = 0.0;
        cfg.beta2 = 1.0;
        cfg.eps = 1e-12;
        cfg.start_preconditioning_step = 1;
        cfg.lr = 0.0; // observe directions only; params stay fixed
        let mut opt = Shampoo::new(&[(6, 4)], cfg);
        let mut params = vec![Matrix::zeros(6, 4)];
        let g = crate::tensor::outer(&u, &v);
        let mut norms = vec![];
        for _ in 0..40 {
            opt.step(&mut params, &[g.clone()]);
            // Direction norm = ‖mu‖ since beta1=0 and lr=0 leaves params.
            norms.push(opt.states[0].mu.fro_norm());
        }
        let ratio = norms[39] / norms[9];
        let expected = (10.0f64 / 40.0).sqrt();
        assert!(
            (ratio - expected).abs() < 0.1 * expected,
            "whitening decay ratio {ratio}, expected ≈ {expected}"
        );
    }

    #[test]
    fn grafting_controls_magnitude() {
        // With RMSProp grafting, per-step magnitude matches the diagonal
        // method's, independent of the preconditioner's raw scale.
        let mut cfg = default_cfg();
        cfg.beta1 = 0.0;
        cfg.weight_decay = 0.0;
        let mut opt = Shampoo::new(&[(3, 3)], cfg);
        let mut rng = Pcg64::new(152);
        let mut params = vec![Matrix::zeros(3, 3)];
        for _ in 0..20 {
            let g = Matrix::randn(3, 3, &mut rng);
            let before = params[0].clone();
            opt.step(&mut params, &[g]);
            let step = params[0].sub(&before).fro_norm() / opt.cfg.lr;
            // Bias-corrected RMSProp step entries are O(1) ⇒ norm ≈ 3.
            assert!(step < 10.0, "graft failed to bound step: {step}");
        }
    }

    #[test]
    fn stat_and_precond_intervals_respected() {
        let mut cfg = default_cfg();
        cfg.stat_interval = 5;
        cfg.precond_interval = 5;
        cfg.start_preconditioning_step = 1;
        let mut opt = Shampoo::new(&[(2, 2)], cfg);
        let mut params = vec![Matrix::zeros(2, 2)];
        let g = Matrix::eye(2);
        opt.step(&mut params, &[g.clone()]);
        // t=1: 1 % 5 != 0 → no stats yet.
        assert_eq!(opt.states[0].unit.l.fro_norm(), 0.0);
        for _ in 0..4 {
            opt.step(&mut params, &[g.clone()]);
        }
        // t=5: stats captured.
        assert!(opt.states[0].unit.l.fro_norm() > 0.0);
    }

    #[test]
    fn memory_is_m2_plus_n2() {
        let opt = Shampoo::new(&[(8, 4)], ShampooConfig::default());
        assert_eq!(opt.second_moment_bytes(), (64 + 16) * 8);
    }

    #[test]
    fn one_sided_converges_and_skips_right_factor() {
        let mut cfg = default_cfg();
        cfg.one_sided = true;
        let mut rng = Pcg64::new(153);
        let target = Matrix::randn(4, 3, &mut rng);
        let mut params = vec![Matrix::zeros(4, 3)];
        let mut opt = Shampoo::new(&[(4, 3)], cfg);
        for _ in 0..3000 {
            let grads = vec![params[0].sub(&target)];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].max_diff(&target) < 0.05);
        // Right factor never accumulated.
        assert_eq!(opt.states[0].unit.r.fro_norm(), 0.0);
        assert!(opt.states[0].unit.r_root.is_none());
    }
}
