//! Vector-world optimizer interface for the OCO experiments.
//!
//! These optimizers act on a single decision vector `x ∈ R^d` with one
//! (sub)gradient per round — the setting of Sec. 2/4 of the paper and of
//! the convex experiments (Appendix A, Observation 2). Deep-learning
//! optimizers over tensor lists live in [`super::matrix_opt`].

/// An online/stochastic optimizer over a flat parameter vector.
pub trait VectorOptimizer {
    /// Display name used in experiment tables.
    fn name(&self) -> String;

    /// One online round: update `x` given subgradient `g`. When `radius`
    /// is set, the iterate is projected back onto the L2 ball of that
    /// radius using the optimizer's own norm (Alg. 2 line 6 for Sketchy;
    /// analogous norms for the baselines).
    fn step(&mut self, x: &mut [f64], g: &[f64], radius: Option<f64>);

    /// Heap memory for optimizer state, in bytes (Fig. 1 accounting).
    fn mem_bytes(&self) -> usize;

    /// Round counter (diagnostics).
    fn steps(&self) -> usize;
}

/// Plain L2 projection onto the ball of radius r.
pub fn project_l2(x: &mut [f64], radius: f64) {
    let n = crate::tensor::norm2(x);
    if n > radius {
        let s = radius / n;
        for v in x.iter_mut() {
            *v *= s;
        }
    }
}
