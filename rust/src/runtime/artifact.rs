//! Artifact registry: manifest parsing, lazy compilation, execution.
//!
//! `Runtime::load(dir)` reads `manifest.json` (written by aot.py), then
//! compiles each HLO-text artifact on first use and caches the
//! executable. Executions go through [`Runtime::execute`], which
//! decomposes the output tuple into literals.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// One input or output tensor description.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    /// Number of leading inputs that are model parameters.
    pub n_params: usize,
    pub n_outputs: usize,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Compiled-executable handle shared across worker threads.
///
/// SAFETY: the `xla` crate wraps raw PJRT pointers (hence `!Send`), but
/// the PJRT C API contract requires clients and loaded executables to be
/// thread-safe, and the TFRT CPU client behind `xla_extension` supports
/// concurrent `Execute` calls. We only ever share immutable references
/// for execution; compilation happens under the registry mutex.
pub struct Exe(pub xla::PjRtLoadedExecutable);
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

/// PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    dir: std::path::PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    // Compiled executables, lazily populated. Mutex (not RwLock): PJRT
    // compilation is the slow path; execution clones the Arc'd exe out.
    compiled: Mutex<HashMap<String, std::sync::Arc<Exe>>>,
}

// SAFETY: see [`Exe`]; the client pointer is thread-safe per the PJRT
// contract and `specs`/`dir` are plain data behind the mutex.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load a manifest directory (`artifacts/` by default).
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest_path = std::path::Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut specs = HashMap::new();
        for art in json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let spec = parse_spec(art)?;
            specs.insert(spec.name.clone(), spec);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.into(),
            specs,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// All artifact names in the manifest.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Exe>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(Exe(self.client.compile(&comp)?));
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the decomposed
    /// output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        let exe = self.executable(name)?;
        let result = exe.0.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        anyhow::ensure!(
            outs.len() == spec.n_outputs,
            "{name}: expected {} outputs, got {}",
            spec.n_outputs,
            outs.len()
        );
        Ok(outs)
    }
}

fn parse_spec(art: &Json) -> Result<ArtifactSpec> {
    let get_str = |k: &str| -> Result<String> {
        art.get(k)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow!("manifest entry missing {k}"))
    };
    let inputs = art
        .get("inputs")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("missing inputs"))?
        .iter()
        .map(|inp| -> Result<IoSpec> {
            Ok(IoSpec {
                name: inp
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                shape: inp
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect(),
                dtype: inp
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let output_shapes = art
        .get("output_shapes")
        .and_then(|v| v.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|s| {
                    s.as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default()
                })
                .collect()
        })
        .unwrap_or_default();
    Ok(ArtifactSpec {
        name: get_str("name")?,
        file: get_str("file")?,
        inputs,
        n_params: art.get("n_params").and_then(|v| v.as_usize()).unwrap_or(0),
        n_outputs: art.get("n_outputs").and_then(|v| v.as_usize()).unwrap_or(1),
        output_shapes,
    })
}

/// Parsed numeric fixture (from fixtures.json) for integration tests.
pub struct Fixture {
    pub inputs: Vec<(String, Vec<usize>, Vec<f64>)>,
    pub outputs: Vec<Vec<f64>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Load one artifact's fixture from `<dir>/fixtures.json`.
pub fn load_fixture(dir: &str, name: &str) -> Result<Fixture> {
    let text = std::fs::read_to_string(std::path::Path::new(dir).join("fixtures.json"))?;
    let json = Json::parse(&text).map_err(|e| anyhow!("fixtures parse: {e}"))?;
    let fx = json
        .get(name)
        .ok_or_else(|| anyhow!("no fixture for {name}"))?;
    let inputs = fx
        .get("inputs")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("fixture missing inputs"))?
        .iter()
        .map(|inp| {
            let name = inp
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            let shape = inp
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default();
            let data = inp
                .get("data")
                .and_then(|v| v.to_f64_vec())
                .unwrap_or_default();
            (name, shape, data)
        })
        .collect();
    let outputs = fx
        .get("outputs")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("fixture missing outputs"))?
        .iter()
        .map(|o| o.to_f64_vec().unwrap_or_default())
        .collect();
    let output_shapes = fx
        .get("output_shapes")
        .and_then(|v| v.as_arr())
        .map(|arr| {
            arr.iter()
                .map(|s| {
                    s.as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default()
                })
                .collect()
        })
        .unwrap_or_default();
    Ok(Fixture { inputs, outputs, output_shapes })
}
