//! Conversions between the Rust tensor types and `xla::Literal`.
//!
//! The Rust optimizer math runs in f64 (numerical headroom for the
//! eigensolvers); artifacts run in f32 (the DL-standard dtype). These
//! helpers are the only place the narrowing happens.

use crate::tensor::Matrix;
use anyhow::Result;

/// f32 literal from a flat buffer + shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch: {shape:?} vs {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal from a flat buffer + shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// f32 literal from an f64 [`Matrix`] (row-major, matching jnp layout).
pub fn matrix_to_lit(m: &Matrix) -> Result<xla::Literal> {
    let data: Vec<f32> = m.as_slice().iter().map(|&x| x as f32).collect();
    lit_f32(&data, &[m.rows(), m.cols()])
}

/// Read a literal back as f64 values (accepts f32 or f64 payloads).
pub fn lit_to_f64(l: &xla::Literal) -> Result<Vec<f64>> {
    match l.ty()? {
        xla::ElementType::F32 => Ok(l.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect()),
        xla::ElementType::F64 => Ok(l.to_vec::<f64>()?),
        other => anyhow::bail!("unsupported element type {other:?}"),
    }
}

/// Scalar f64 from a literal.
pub fn lit_scalar(l: &xla::Literal) -> Result<f64> {
    let v = lit_to_f64(l)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

/// Literal → Matrix with the given shape (flattens >2-D shapes into
/// (rows, prod(rest)) since all our parameters are 2-D by construction).
pub fn lit_to_matrix(l: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = lit_to_f64(l)?;
    anyhow::ensure!(v.len() == rows * cols, "size mismatch {} vs {rows}x{cols}", v.len());
    Ok(Matrix::from_vec(rows, cols, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let m = lit_to_matrix(&lit, 2, 3).unwrap();
        assert_eq!(m[(1, 2)], 6.0);
    }

    #[test]
    fn matrix_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.5], vec![0.25, 4.0]]);
        let lit = matrix_to_lit(&m).unwrap();
        let back = lit_to_matrix(&lit, 2, 2).unwrap();
        assert!(back.max_diff(&m) < 1e-6);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2]).is_err());
    }

    #[test]
    fn i32_literal() {
        let lit = lit_i32(&[7, 8, 9], &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }
}
