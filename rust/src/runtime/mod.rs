//! Process runtime: the PJRT artifact plane (system S7) and the
//! persistent worker-pool substrate every parallel phase runs on.
//!
//! [`artifact`] loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust training
//! path (Python never runs at training time). [`pool`] is the
//! process-wide pool of long-lived worker threads behind the dense
//! kernels (`tensor::ops`) and the block engine (`optim::engine`).

pub mod artifact;
pub mod literal;
pub mod pool;

pub use artifact::{ArtifactSpec, IoSpec, Runtime};
pub use pool::WorkerPool;
