//! PJRT runtime (system S7): loads the AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them from the Rust
//! training path. Python never runs at training time.

pub mod artifact;
pub mod literal;

pub use artifact::{ArtifactSpec, IoSpec, Runtime};
