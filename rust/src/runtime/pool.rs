//! Persistent worker-pool runtime.
//!
//! Before this module, every parallel phase in the system paid thread
//! startup on the hot path: `tensor::ops::matmul_into` spawned a
//! `std::thread::scope` per call, and the block engine's
//! `optim::engine::drive_all` spawned a fresh scope per step. At paper
//! block counts the work per phase is milliseconds, so per-call spawn +
//! join overhead is a measurable tax (the `engine/step_overhead` bench
//! tracks it). This module replaces both with one process-wide pool of
//! **long-lived** workers and a phase barrier:
//!
//! - [`WorkerPool::run`] — the synchronous phase: partition `n_tasks`
//!   indexed tasks across at most `parallelism` participants (the caller
//!   itself is one — it claims tasks too, so tiny phases often finish
//!   without a single context switch), then barrier until every task
//!   completed. Task *claiming* is self-scheduling (an atomic cursor,
//!   the same discipline as the engine's old `BoundedQueue` work list),
//!   so one slow task never idles the rest of the pool.
//! - [`WorkerPool::spawn`] — the asynchronous phase used by the engine's
//!   `RefreshAhead` stage: enqueue an owned job and get a [`JobHandle`]
//!   to barrier on later, so eigendecompositions overlap with the
//!   trainer's gradient computation between engine steps.
//!
//! **Determinism contract:** the pool never decides *what* is computed,
//! only *where*. Callers partition work exactly as the old scoped-thread
//! code did (chunk boundaries are the caller's), every task writes
//! disjoint output, and no cross-task reduction happens inside the pool
//! — so results are bitwise identical to the serial path for any worker
//! count, including zero (`tests/pool_runtime.rs`).
//!
//! **Panic contract:** a panicking task is caught on the worker, the
//! phase still completes (remaining tasks run), and the first panic is
//! reported as an error naming the task index. [`WorkerPool::run`]
//! re-raises it on the caller; [`WorkerPool::try_run`] and
//! [`JobHandle::wait`] surface it as `Err`.
//!
//! Nested use is safe by construction: a task that itself calls
//! [`WorkerPool::run`] (e.g. a dense kernel invoked from an engine block
//! task that forgot the single-thread pin) executes inline on the worker
//! instead of re-entering the pool, so the pool can never deadlock on
//! itself or oversubscribe cores.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set while a pool worker (or a caller inside `run`) executes a
    /// task; nested `run`/`try_run` calls then execute inline.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is executing a pool task (nested parallel
/// phases run inline).
pub fn in_pool_task() -> bool {
    IN_POOL_TASK.with(|w| w.get())
}

fn enter_task<R>(f: impl FnOnce() -> R) -> R {
    /// Restores the flag on drop so a panicking task (caught by the
    /// pool's `catch_unwind`) cannot leave the thread marked in-task —
    /// that would silently serialize every later phase on this thread.
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            IN_POOL_TASK.with(|w| w.set(self.0));
        }
    }
    let prev = IN_POOL_TASK.with(|w| w.replace(true));
    let _reset = Reset(prev);
    f()
}

/// Raw pointer to a `run` caller's stack closure. A *pointer* (not a
/// reference) on purpose: workers may retain the `Arc<Job>` briefly
/// after `run`'s barrier, and a dangling raw pointer that is never
/// dereferenced is sound where a dangling reference value would not be.
/// `run` barriers on full completion before the referent frame unwinds,
/// so every dereference (in [`TaskBody::call`]) happens while the
/// closure is alive.
struct BorrowedTask(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is a `Sync` closure shared across threads only
// for the duration of the phase barrier (see above).
unsafe impl Send for BorrowedTask {}
unsafe impl Sync for BorrowedTask {}

/// The work of one job: an indexed task body.
enum TaskBody {
    Borrowed(BorrowedTask),
    Owned(Box<dyn Fn(usize) + Send + Sync + 'static>),
}

impl TaskBody {
    fn call(&self, i: usize) {
        match self {
            // SAFETY: only invoked for claimed tasks, all of which
            // complete before `run` returns and the closure frame dies.
            TaskBody::Borrowed(p) => unsafe { (*p.0)(i) },
            TaskBody::Owned(f) => f(i),
        }
    }
}

/// One parallel phase: an indexed task body plus claim/complete state.
struct Job {
    body: TaskBody,
    n_tasks: usize,
    /// Max participants (callers + workers) allowed to claim tasks.
    limit: usize,
    /// Participation gate.
    participants: AtomicUsize,
    /// Self-scheduling task cursor.
    next: AtomicUsize,
    /// Completed-task count. Atomic (not under the mutex) so tiny-task
    /// phases — the dispatch-overhead case this pool exists for — pay
    /// one uncontended RMW per task instead of a contended lock.
    completed: AtomicUsize,
    /// First captured panic, as "task {i} panicked: {msg}". Doubles as
    /// the condvar mutex for the completion barrier.
    panic: Mutex<Option<String>>,
    done_cv: Condvar,
}

impl Job {
    fn new(body: TaskBody, n_tasks: usize, limit: usize) -> Job {
        Job {
            body,
            n_tasks,
            limit,
            participants: AtomicUsize::new(0),
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panic: Mutex::new(None),
            done_cv: Condvar::new(),
        }
    }

    /// Whether a scanning worker could still contribute.
    fn has_claimable(&self) -> bool {
        self.participants.load(Ordering::Relaxed) < self.limit
            && self.next.load(Ordering::Relaxed) < self.n_tasks
    }

    /// Whether every task index has been claimed (not necessarily done).
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_tasks
    }

    /// Record a task's panic message (first wins).
    fn record_panic(&self, msg: String) {
        let mut p = self.panic.lock().unwrap();
        if p.is_none() {
            *p = Some(msg);
        }
    }

    /// Count one task done; the last completion wakes the barrier. The
    /// `AcqRel` RMW chain is also what publishes task side effects to
    /// the thread that returns from [`Job::wait_done`].
    fn complete_one(&self) {
        if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.n_tasks {
            // Take the barrier mutex before notifying so a waiter that
            // checked the count but not yet parked cannot miss the wake.
            let _guard = self.panic.lock().unwrap();
            self.done_cv.notify_all();
        }
    }

    /// Participate: claim and execute tasks until the cursor runs out.
    /// Panics in task bodies are caught and recorded; the phase always
    /// completes.
    fn execute(&self) {
        if self.participants.fetch_add(1, Ordering::Relaxed) >= self.limit {
            self.participants.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                break;
            }
            let result = catch_unwind(AssertUnwindSafe(|| enter_task(|| self.body.call(i))));
            if let Err(payload) = result {
                self.record_panic(format!("task {i} panicked: {}", panic_message(&payload)));
            }
            self.complete_one();
        }
    }

    /// Claim every not-yet-claimed task and complete it as failed —
    /// used by pool drop so outstanding [`JobHandle::wait`] calls
    /// return an error instead of hanging on tasks that will never run.
    fn abort_unclaimed(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                break;
            }
            self.record_panic(format!("task {i} dropped: pool shut down before it ran"));
            self.complete_one();
        }
    }

    /// Barrier until every task completed; returns the first panic.
    fn wait_done(&self) -> Option<String> {
        let mut p = self.panic.lock().unwrap();
        while self.completed.load(Ordering::Acquire) < self.n_tasks {
            p = self.done_cv.wait(p).unwrap();
        }
        p.take()
    }
}

/// Extract a human-readable message from a caught panic payload (shared
/// with the engine's serial block phase, which catches its own panics).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct PoolState {
    jobs: VecDeque<Arc<Job>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// Handle to an asynchronously [`WorkerPool::spawn`]ed job.
pub struct JobHandle {
    job: Arc<Job>,
}

impl JobHandle {
    /// Barrier until the job completed. `Err` carries the first task
    /// panic, naming the task index.
    pub fn wait(self) -> Result<(), String> {
        match self.job.wait_done() {
            Some(msg) => Err(msg),
            None => Ok(()),
        }
    }
}

/// A pool of persistent worker threads (see the module docs).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Pool with `workers` threads started eagerly. More are added on
    /// demand by `run`/`spawn` (growth only; threads live until drop).
    pub fn new(workers: usize) -> WorkerPool {
        let pool = WorkerPool {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
                work_cv: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
        };
        pool.ensure_workers(workers);
        pool
    }

    /// Current persistent worker-thread count.
    pub fn workers(&self) -> usize {
        self.handles.lock().unwrap().len()
    }

    /// Grow the pool to at least `n` worker threads.
    pub fn ensure_workers(&self, n: usize) {
        let mut handles = self.handles.lock().unwrap();
        while handles.len() < n {
            let shared = Arc::clone(&self.shared);
            let id = handles.len();
            let h = std::thread::Builder::new()
                .name(format!("sketchy-pool-{id}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
            handles.push(h);
        }
    }

    /// Run `f(0..n_tasks)` across at most `parallelism` participants and
    /// barrier until every task completed. Bitwise-deterministic: task
    /// partition and arithmetic are the caller's; the pool only assigns
    /// indices to threads. Panics in tasks re-raise here, naming the
    /// task — use [`WorkerPool::try_run`] for the `Result` form.
    pub fn run<F: Fn(usize) + Sync>(&self, parallelism: usize, n_tasks: usize, f: F) {
        if let Err(msg) = self.try_run(parallelism, n_tasks, f) {
            panic!("worker pool: {msg}");
        }
    }

    /// [`WorkerPool::run`], but a task panic is returned as `Err`
    /// naming the task instead of re-raised.
    pub fn try_run<F: Fn(usize) + Sync>(
        &self,
        parallelism: usize,
        n_tasks: usize,
        f: F,
    ) -> Result<(), String> {
        if n_tasks == 0 {
            return Ok(());
        }
        let limit = parallelism.max(1).min(n_tasks);
        if limit <= 1 || in_pool_task() {
            // Serial (or nested) phase: execute inline. Same arithmetic,
            // same panic surface.
            let mut panic: Option<String> = None;
            for i in 0..n_tasks {
                let r = catch_unwind(AssertUnwindSafe(|| enter_task(|| f(i))));
                if let Err(payload) = r {
                    if panic.is_none() {
                        panic = Some(format!("task {i} panicked: {}", panic_message(&payload)));
                    }
                }
            }
            return match panic {
                Some(msg) => Err(msg),
                None => Ok(()),
            };
        }
        // The caller is one participant; workers supply the rest.
        self.ensure_workers(limit - 1);
        // Lifetime erasure for the borrowed task body: `wait_done` below
        // barriers on full completion before this frame unwinds. The
        // erased form is stored as a raw pointer, so a worker briefly
        // outliving the frame holds a dangling pointer (fine) rather
        // than a dangling reference (not fine); the transient `&'static`
        // below exists only while the closure is demonstrably alive.
        let body: &(dyn Fn(usize) + Sync) = &f;
        let body: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(body)
        };
        let body = BorrowedTask(body as *const (dyn Fn(usize) + Sync));
        let job = Arc::new(Job::new(TaskBody::Borrowed(body), n_tasks, limit));
        self.enqueue(&job);
        job.execute();
        let panic = job.wait_done();
        self.retire(&job);
        match panic {
            Some(msg) => Err(msg),
            None => Ok(()),
        }
    }

    /// Enqueue an owned job and return a handle to barrier on later.
    /// Used by the engine's RefreshAhead stage: the job runs on pool
    /// workers while the caller goes on to other work (the caller does
    /// not participate). At least one worker is ensured.
    pub fn spawn(
        &self,
        parallelism: usize,
        n_tasks: usize,
        f: impl Fn(usize) + Send + Sync + 'static,
    ) -> JobHandle {
        let limit = parallelism.max(1).min(n_tasks.max(1));
        let job = Arc::new(Job::new(TaskBody::Owned(Box::new(f)), n_tasks, limit));
        if n_tasks > 0 {
            self.ensure_workers(limit);
            self.enqueue(&job);
        }
        // n_tasks == 0: completed == n_tasks already; wait() returns
        // immediately and nothing was queued.
        JobHandle { job }
    }

    fn enqueue(&self, job: &Arc<Job>) {
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(Arc::clone(job));
        drop(st);
        self.shared.work_cv.notify_all();
    }

    /// Remove a finished job from the queue (workers also retire jobs
    /// they observe exhausted; double removal is harmless).
    fn retire(&self, job: &Arc<Job>) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(pos) = st.jobs.iter().position(|j| Arc::ptr_eq(j, job)) {
            st.jobs.remove(pos);
        }
    }
}

impl Drop for WorkerPool {
    /// Signal shutdown and join every worker. Workers finish the tasks
    /// they already claimed (a participant drains its claim loop before
    /// checking shutdown), so `run` callers always complete. Spawned
    /// jobs whose tasks were never claimed are aborted after the join —
    /// their outstanding [`JobHandle::wait`] calls return an error
    /// naming the dropped task instead of hanging forever.
    fn drop(&mut self) {
        let drained: Vec<Arc<Job>> = {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            st.jobs.drain(..).collect()
        };
        self.shared.work_cv.notify_all();
        for h in self.handles.get_mut().unwrap().drain(..) {
            let _ = h.join();
        }
        // After the join no worker can claim anything; fail what's left.
        for job in drained {
            job.abort_unclaimed();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                // Retire exhausted jobs so the scan stays short, then
                // pick the first job with claimable work.
                st.jobs.retain(|j| !j.exhausted());
                if let Some(j) = st.jobs.iter().find(|j| j.has_claimable()) {
                    break Arc::clone(j);
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        job.execute();
    }
}

/// The process-wide pool shared by the dense kernels and the block
/// engine. Created on first use with zero workers; grows to match the
/// parallelism callers ask for (bounded by `tensor::ops::num_threads`
/// resolution and engine thread knobs, which cap at core count).
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_executes_every_task_once() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} hit count");
        }
    }

    #[test]
    fn serial_and_zero_task_paths() {
        let pool = WorkerPool::new(0);
        let sum = AtomicUsize::new(0);
        // parallelism 1 never touches workers.
        pool.run(1, 10, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
        pool.run(4, 0, |_| panic!("zero tasks must not run"));
        assert_eq!(pool.workers(), 0, "serial phases must not grow the pool");
    }

    #[test]
    fn panic_is_reported_naming_the_task() {
        let pool = WorkerPool::new(2);
        let err = pool
            .try_run(3, 8, |i| {
                if i == 5 {
                    panic!("boom");
                }
            })
            .expect_err("panicking task must surface");
        assert!(err.contains("task 5"), "error must name the task: {err}");
        assert!(err.contains("boom"), "error must carry the payload: {err}");
        // The pool survives the panic and keeps working.
        let ok = pool.try_run(3, 8, |_| {});
        assert!(ok.is_ok());
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = WorkerPool::new(2);
        let outer_hits = AtomicUsize::new(0);
        let inner_hits = AtomicUsize::new(0);
        pool.run(2, 4, |_| {
            outer_hits.fetch_add(1, Ordering::Relaxed);
            assert!(in_pool_task());
            // A nested phase must not re-enter the pool (deadlock risk);
            // it runs inline on this participant.
            global().run(4, 3, |_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer_hits.load(Ordering::Relaxed), 4);
        assert_eq!(inner_hits.load(Ordering::Relaxed), 12);
        assert!(!in_pool_task(), "task flag leaked past run");
    }

    #[test]
    fn spawn_runs_in_background_and_wait_barriers() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = {
            let hits = Arc::clone(&hits);
            pool.spawn(2, 16, move |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            })
        };
        h.wait().expect("background job");
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        // Zero-task spawn completes immediately.
        pool.spawn(2, 0, |_| panic!("no tasks")).wait().unwrap();
    }

    #[test]
    fn spawned_panic_surfaces_in_wait() {
        let pool = WorkerPool::new(1);
        let err = pool
            .spawn(1, 4, |i| {
                if i == 2 {
                    panic!("bg boom");
                }
            })
            .wait()
            .expect_err("background panic must surface");
        assert!(err.contains("task 2") && err.contains("bg boom"), "{err}");
    }

    #[test]
    fn drop_fails_outstanding_spawned_jobs_instead_of_hanging() {
        let pool = WorkerPool::new(0);
        // Occupy the lone worker (ensured by spawn) with a gated job,
        // confirmed started, then queue a second job behind it.
        let gate = Arc::new((Mutex::new((false, false)), Condvar::new()));
        let g = Arc::clone(&gate);
        let h1 = pool.spawn(1, 1, move |_| {
            let (m, cv) = &*g;
            let mut st = m.lock().unwrap();
            st.0 = true; // started
            cv.notify_all();
            while !st.1 {
                st = cv.wait(st).unwrap();
            }
        });
        {
            let (m, cv) = &*gate;
            let mut st = m.lock().unwrap();
            while !st.0 {
                st = cv.wait(st).unwrap();
            }
            let h2 = pool.spawn(1, 4, |_| {});
            st.1 = true; // release the worker
            cv.notify_all();
            drop(st);
            drop(pool);
            // h1 was claimed before the shutdown, so it completed; h2
            // may have run or been aborted — either way wait() must
            // return rather than hang.
            h1.wait().expect("claimed job must complete");
            let _ = h2.wait();
        }
    }

    #[test]
    fn shutdown_and_rebuild() {
        let pool = WorkerPool::new(3);
        pool.run(3, 9, |_| {});
        assert_eq!(pool.workers(), 3);
        drop(pool); // joins workers
        let pool = WorkerPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.run(2, 5, |i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 15);
    }

    #[test]
    fn pool_grows_on_demand_and_caps_at_task_count() {
        let pool = WorkerPool::new(0);
        pool.run(8, 2, |_| {});
        // limit = min(8, 2) = 2 participants; caller is one.
        assert_eq!(pool.workers(), 1);
        pool.run(3, 100, |_| {});
        assert_eq!(pool.workers(), 2);
    }

    #[test]
    fn concurrent_runs_from_multiple_threads() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        let mut joins = vec![];
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            joins.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    pool.run(3, 16, |_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 16);
    }
}
