//! Dense reference implementation of Algorithm 1 (FD-update).
//!
//! Materializes the d×d covariance and follows the paper's pseudocode
//! line by line. It exists purely as a test oracle: property tests check
//! that the factored [`super::fd::FdSketch`] matches this reference on
//! random streams, including under exponential weighting.

use crate::tensor::{eigh, Matrix};

/// Dense FD state: Ḡ plus escaped-mass accounting.
#[derive(Clone)]
pub struct DenseFd {
    pub gbar: Matrix,
    pub ell: usize,
    pub rho_sum: f64,
    pub decay: f64,
}

impl DenseFd {
    pub fn new(d: usize, ell: usize, decay: f64) -> Self {
        DenseFd { gbar: Matrix::zeros(d, d), ell, rho_sum: 0.0, decay }
    }

    /// Alg. 1: eigendecompose Ḡ_{t-1}·β₂ + M_t, keep top ℓ directions,
    /// deflate uniformly by λ_ℓ. Returns ρ_t = λ_ℓ.
    pub fn update(&mut self, news: &Matrix) -> f64 {
        let d = self.gbar.rows();
        let mut m = self.gbar.scale(self.decay);
        m.axpy(1.0, news);
        let e = eigh(&m);
        let rho = if d >= self.ell { e.w[self.ell - 1].max(0.0) } else { 0.0 };
        // Ḡ_t = Σ_{i<ℓ} (λ_i − λ_ℓ)₊ u_i u_iᵀ.
        let mut g = Matrix::zeros(d, d);
        for j in 0..self.ell.min(d) {
            let w = (e.w[j] - rho).max(0.0);
            if w == 0.0 {
                continue;
            }
            for i in 0..d {
                let uij = e.q[(i, j)] * w;
                for i2 in 0..d {
                    g[(i, i2)] += uij * e.q[(i2, j)];
                }
            }
        }
        self.gbar = g;
        self.rho_sum = self.decay * self.rho_sum + rho;
        rho
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::fd::FdSketch;
    use crate::tensor::outer;
    use crate::util::proptest::for_all_msg;
    use crate::util::rng::Pcg64;

    /// Factored FdSketch must match the dense Alg. 1 reference on random
    /// rank-1 streams (the Alg. 2 setting).
    #[test]
    fn prop_factored_matches_dense_rank1() {
        for_all_msg(
            90,
            12,
            |rng| {
                let d = 4 + rng.below(8);
                let ell = 2 + rng.below(d - 2);
                let t = 5 + rng.below(25);
                let seed = rng.next_u64();
                (d, ell, t, seed)
            },
            |&(d, ell, t, seed)| {
                let mut rng = Pcg64::new(seed);
                let mut fac = FdSketch::new(d, ell, 1.0);
                let mut dense = DenseFd::new(d, ell, 1.0);
                for step in 0..t {
                    let g: Vec<f64> = (0..d)
                        .map(|i| rng.gaussian() / (1.0 + i as f64).sqrt())
                        .collect();
                    let r1 = fac.update_vec(&g);
                    let r2 = dense.update(&outer(&g, &g));
                    if (r1 - r2).abs() > 1e-7 * (1.0 + r2.abs()) {
                        return Err(format!("step {step}: rho {r1} vs {r2}"));
                    }
                    let diff = fac.materialize().max_diff(&dense.gbar);
                    if diff > 1e-6 * (1.0 + dense.gbar.max_abs()) {
                        return Err(format!("step {step}: sketch diff {diff}"));
                    }
                }
                if (fac.escaped_mass() - dense.rho_sum).abs() > 1e-6 {
                    return Err(format!(
                        "rho_sum {} vs {}",
                        fac.escaped_mass(),
                        dense.rho_sum
                    ));
                }
                Ok(())
            },
        );
    }

    /// Same equivalence under exponential weighting (Obs. 6) and
    /// matrix-valued news (the Shampoo setting).
    #[test]
    fn prop_factored_matches_dense_ema_matrix_news() {
        for_all_msg(
            91,
            8,
            |rng| {
                let d = 4 + rng.below(6);
                let ell = 2 + rng.below(d - 2);
                let r = 1 + rng.below(3);
                let t = 4 + rng.below(12);
                let seed = rng.next_u64();
                (d, ell, r, t, seed)
            },
            |&(d, ell, r, t, seed)| {
                let mut rng = Pcg64::new(seed);
                let beta2 = 0.9;
                let mut fac = FdSketch::new(d, ell, beta2);
                let mut dense = DenseFd::new(d, ell, beta2);
                for step in 0..t {
                    let y = Matrix::randn(d, r, &mut rng);
                    let news = crate::tensor::a_bt(&y, &y);
                    fac.update(&y);
                    dense.update(&news);
                    let diff = fac.materialize().max_diff(&dense.gbar);
                    if diff > 1e-6 * (1.0 + dense.gbar.max_abs()) {
                        return Err(format!("step {step}: diff {diff}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Obs. 6 bound: ‖Ḡ_T − G_T‖ ≤ ρ_{1:T} ≤ tail/(ℓ−k) for the EMA
    /// covariance.
    #[test]
    fn ema_error_bound_observation6() {
        let mut rng = Pcg64::new(92);
        let d = 8;
        let ell = 4;
        let beta2 = 0.95;
        let mut fd = FdSketch::new(d, ell, beta2);
        let mut exact = Matrix::zeros(d, d);
        for _ in 0..60 {
            let g: Vec<f64> = (0..d).map(|i| rng.gaussian() / (1 << i.min(6)) as f64).collect();
            fd.update_vec(&g);
            exact.scale_inplace(beta2);
            exact.axpy(1.0, &outer(&g, &g));
        }
        let err = crate::tensor::eigh(&fd.materialize().sub(&exact));
        let op_norm = err.w.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        assert!(
            op_norm <= fd.escaped_mass() + 1e-8,
            "‖Ḡ−G‖={op_norm} > ρ={}",
            fd.escaped_mass()
        );
    }
}
