//! Factored PSD operators: apply spectral functions of `U diag(w) Uᵀ + ρI`
//! in O(d·ℓ) without materializing anything d×d.
//!
//! This is where Sketchy's memory story cashes out: Alg. 2's descent
//! direction `G̃⁻¹ᐟ² g` and Alg. 3's `L̃⁻¹ᐟ⁴ G R̃⁻¹ᐟ⁴` are computed from the
//! sketch factors directly. For `f` applied to `G̃ = U diag(w) Uᵀ + ρ P_U +
//! ρ P_⊥` (P_⊥ the complement projector):
//!
//! `f(G̃) x = U (f(w+ρ) − f(ρ)) ⊙ (Uᵀx) + f(ρ)·x`
//!
//! With ρ = 0 the pseudo-inverse convention of Alg. 2 applies: the
//! complement coefficient f(0) is taken as 0 for negative powers.

use crate::tensor::{Matrix, at_b, matmul};

/// Borrowed view of a factored PSD operator `U diag(w) Uᵀ + shift·I`.
pub struct FactoredPsd<'a> {
    /// Orthonormal basis, d×ℓ (zero columns beyond `active`).
    pub u: &'a Matrix,
    /// Eigenvalues of the low-rank part (descending, len ℓ).
    pub w: &'a [f64],
    /// Diagonal shift ρ ≥ 0.
    pub shift: f64,
    /// Number of active (positive) eigenvalues.
    pub active: usize,
}

impl<'a> FactoredPsd<'a> {
    /// Spectral coefficients for `f(λ) = (λ)^{-1/p}` with pseudo-inverse
    /// handling at 0: returns (per-eigendirection coefficient minus the
    /// complement coefficient, complement coefficient).
    fn inv_root_coeffs(&self, p: f64) -> (Vec<f64>, f64) {
        let f = |lam: f64| -> f64 {
            if lam > 0.0 {
                lam.powf(-1.0 / p)
            } else {
                0.0 // Moore–Penrose: null directions get 0.
            }
        };
        let comp = f(self.shift);
        let coeffs = (0..self.active)
            .map(|i| f(self.w[i] + self.shift) - comp)
            .collect();
        (coeffs, comp)
    }

    /// `y = G̃^{-1/p} x` for a vector x, in O(dℓ).
    pub fn apply_inv_root_vec(&self, p: f64, x: &[f64]) -> Vec<f64> {
        let d = self.u.rows();
        assert_eq!(x.len(), d);
        let (coeffs, comp) = self.inv_root_coeffs(p);
        // c = Uᵀ x (active columns only).
        let mut y: Vec<f64> = x.iter().map(|&v| comp * v).collect();
        for (j, &cj) in coeffs.iter().enumerate() {
            let mut proj = 0.0;
            for i in 0..d {
                proj += self.u[(i, j)] * x[i];
            }
            let scale = cj * proj;
            for (i, yi) in y.iter_mut().enumerate() {
                *yi += scale * self.u[(i, j)];
            }
        }
        y
    }

    /// `Y = G̃^{-1/p} X` applied from the left to a d×n matrix, O(dℓn).
    pub fn apply_inv_root_left(&self, p: f64, x: &Matrix) -> Matrix {
        let d = self.u.rows();
        assert_eq!(x.rows(), d);
        let (coeffs, comp) = self.inv_root_coeffs(p);
        let k = coeffs.len();
        let mut y = x.scale(comp);
        if k == 0 {
            return y;
        }
        let ua = self.u.slice(0, d, 0, k);
        // P = Uᵀ X (k×n), then Y += U diag(coeffs) P.
        let mut proj = at_b(&ua, x);
        for (j, &cj) in coeffs.iter().enumerate() {
            for v in proj.row_mut(j) {
                *v *= cj;
            }
        }
        let corr = matmul(&ua, &proj);
        y.axpy(1.0, &corr);
        y
    }

    /// `Y = X G̃^{-1/p}` applied from the right to an n×d matrix, O(dℓn).
    pub fn apply_inv_root_right(&self, p: f64, x: &Matrix) -> Matrix {
        let d = self.u.rows();
        assert_eq!(x.cols(), d);
        let (coeffs, comp) = self.inv_root_coeffs(p);
        let k = coeffs.len();
        let mut y = x.scale(comp);
        if k == 0 {
            return y;
        }
        let ua = self.u.slice(0, d, 0, k);
        // P = X U (n×k), then Y += P diag(coeffs) Uᵀ.
        let mut proj = matmul(x, &ua);
        for j in 0..k {
            let cj = coeffs[j];
            for i in 0..proj.rows() {
                proj[(i, j)] *= cj;
            }
        }
        let corr = crate::tensor::a_bt(&proj, &ua);
        y.axpy(1.0, &corr);
        y
    }

    /// The matrix norm ‖x‖²_{G̃^{1/2}} = xᵀ G̃^{1/2} x (used by Alg. 2's
    /// projection step).
    pub fn quad_form_sqrt(&self, x: &[f64]) -> f64 {
        let d = self.u.rows();
        let f = |lam: f64| lam.max(0.0).sqrt();
        let comp = f(self.shift);
        let mut total = comp * crate::tensor::dot(x, x);
        for j in 0..self.active {
            let mut proj = 0.0;
            for i in 0..d {
                proj += self.u[(i, j)] * x[i];
            }
            total += (f(self.w[j] + self.shift) - comp) * proj * proj;
        }
        total
    }

    /// Materialize G̃ (tests only).
    pub fn materialize(&self) -> Matrix {
        let d = self.u.rows();
        let mut m = Matrix::zeros(d, d);
        for j in 0..self.active {
            for i in 0..d {
                let uij = self.u[(i, j)] * self.w[j];
                for i2 in 0..d {
                    m[(i, i2)] += uij * self.u[(i2, j)];
                }
            }
        }
        m.add_diag(self.shift);
        m
    }

    /// Projection onto the Euclidean ball of radius `radius` in the norm
    /// ‖·‖_{G̃^{1/2}} (Alg. 2 line 6): solves
    /// `argmin_{‖x‖₂ ≤ radius} ‖x − y‖²_{G̃^{1/2}}` by bisection on the KKT
    /// multiplier in the sketch eigenbasis — O(dℓ + ℓ·iters).
    pub fn project_ball(&self, y: &[f64], radius: f64) -> Vec<f64> {
        let d = self.u.rows();
        let nrm = crate::tensor::norm2(y);
        if nrm <= radius {
            return y.to_vec();
        }
        // M = G̃^{1/2}: eigenvalues m_j = sqrt(w_j + shift) on basis
        // directions, m_perp = sqrt(shift) on the complement. A zero
        // m_perp (unshifted, rank-deficient) makes the complement
        // component free; we then simply rescale it to feasibility.
        let f = |lam: f64| (lam.max(0.0)).sqrt();
        let m_perp = f(self.shift);
        let m_dir: Vec<f64> = (0..self.active).map(|j| f(self.w[j] + self.shift)).collect();
        // Coefficients of y in the basis and the complement residual.
        let mut coeff = vec![0.0; self.active];
        let mut resid = y.to_vec();
        for j in 0..self.active {
            let mut proj = 0.0;
            for i in 0..d {
                proj += self.u[(i, j)] * y[i];
            }
            coeff[j] = proj;
            for i in 0..d {
                resid[i] -= proj * self.u[(i, j)];
            }
        }
        let resid_norm2 = crate::tensor::dot(&resid, &resid);
        // x(ν) = (M + νI)^{-1} M y componentwise; ‖x(ν)‖₂ decreasing in ν.
        let xnorm2 = |nu: f64| -> f64 {
            let mut s = 0.0;
            for j in 0..self.active {
                let c = m_dir[j] / (m_dir[j] + nu) * coeff[j];
                s += c * c;
            }
            let cperp = if m_perp + nu > 0.0 { m_perp / (m_perp + nu) } else { 0.0 };
            s + cperp * cperp * resid_norm2
        };
        // Bisection for ‖x(ν)‖ = radius.
        let mut lo = 0.0;
        let mut hi = 1.0;
        while xnorm2(hi) > radius * radius && hi < 1e18 {
            hi *= 2.0;
        }
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            if xnorm2(mid) > radius * radius {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let nu = 0.5 * (lo + hi);
        // Assemble x(ν).
        let cperp = if m_perp + nu > 0.0 { m_perp / (m_perp + nu) } else { 0.0 };
        let mut x: Vec<f64> = resid.iter().map(|&r| cperp * r).collect();
        for j in 0..self.active {
            let c = m_dir[j] / (m_dir[j] + nu) * coeff[j];
            for i in 0..d {
                x[i] += c * self.u[(i, j)];
            }
        }
        // Guard: numerical safety rescale.
        let n = crate::tensor::norm2(&x);
        if n > radius {
            for v in &mut x {
                *v *= radius / n;
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{eigh, matvec, random_orthonormal};
    use crate::util::rng::Pcg64;

    /// Build a random factored operator and its dense materialization.
    fn random_factored(
        d: usize,
        k: usize,
        shift: f64,
        seed: u64,
    ) -> (Matrix, Vec<f64>, Matrix) {
        let mut rng = Pcg64::new(seed);
        let u = random_orthonormal(d, k, &mut rng);
        let mut w: Vec<f64> = (0..k).map(|i| 4.0 / (1.0 + i as f64)).collect();
        w.push(0.0); // emulate the zero ℓ-th eigenvalue
        let mut u_pad = Matrix::zeros(d, k + 1);
        u_pad.set_slice(0, 0, &u);
        let fac = FactoredPsd { u: &u_pad, w: &w, shift, active: k };
        let dense = fac.materialize();
        (u_pad, w, dense)
    }

    #[test]
    fn inv_root_vec_matches_dense() {
        let d = 10;
        let k = 3;
        for &shift in &[0.5, 2.0] {
            let (u, w, dense) = random_factored(d, k, shift, 70);
            let fac = FactoredPsd { u: &u, w: &w, shift, active: k };
            let e = eigh(&dense);
            let mut rng = Pcg64::new(71);
            let x = rng.gaussian_vec(d);
            for &p in &[2.0, 4.0] {
                let dense_root = e.apply_spectral(|lam| lam.max(1e-300).powf(-1.0 / p));
                let want = matvec(&dense_root, &x);
                let got = fac.apply_inv_root_vec(p, &x);
                for i in 0..d {
                    assert!(
                        (want[i] - got[i]).abs() < 1e-8,
                        "p={p} shift={shift} i={i}: {} vs {}",
                        want[i],
                        got[i]
                    );
                }
            }
        }
    }

    #[test]
    fn inv_root_zero_shift_is_pseudoinverse() {
        // With shift=0 the complement must map to 0 (Moore–Penrose).
        let d = 8;
        let k = 2;
        let (u, w, dense) = random_factored(d, k, 0.0, 72);
        let fac = FactoredPsd { u: &u, w: &w, shift: 0.0, active: k };
        let mut rng = Pcg64::new(73);
        let x = rng.gaussian_vec(d);
        let got = fac.apply_inv_root_vec(2.0, &x);
        // Dense pinv sqrt.
        let pinv = crate::tensor::pinv_sqrt(&dense, 1e-12);
        let want = matvec(&pinv, &x);
        for i in 0..d {
            assert!((want[i] - got[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn left_right_matrix_applies_match_dense() {
        let d = 9;
        let k = 4;
        let shift = 1.3;
        let (u, w, dense) = random_factored(d, k, shift, 74);
        let fac = FactoredPsd { u: &u, w: &w, shift, active: k };
        let e = eigh(&dense);
        let droot = e.apply_spectral(|lam| lam.max(1e-300).powf(-0.25));
        let mut rng = Pcg64::new(75);
        let x = Matrix::randn(d, 5, &mut rng);
        let got = fac.apply_inv_root_left(4.0, &x);
        let want = matmul(&droot, &x);
        assert!(got.max_diff(&want) < 1e-8);
        let xr = Matrix::randn(5, d, &mut rng);
        let got_r = fac.apply_inv_root_right(4.0, &xr);
        let want_r = matmul(&xr, &droot);
        assert!(got_r.max_diff(&want_r) < 1e-8);
    }

    #[test]
    fn quad_form_matches_dense() {
        let d = 7;
        let k = 3;
        let shift = 0.8;
        let (u, w, dense) = random_factored(d, k, shift, 76);
        let fac = FactoredPsd { u: &u, w: &w, shift, active: k };
        let e = eigh(&dense);
        let sqrt_m = e.apply_spectral(|lam| lam.max(0.0).sqrt());
        let mut rng = Pcg64::new(77);
        let x = rng.gaussian_vec(d);
        let mx = matvec(&sqrt_m, &x);
        let want = crate::tensor::dot(&x, &mx);
        let got = fac.quad_form_sqrt(&x);
        assert!((want - got).abs() < 1e-8 * (1.0 + want.abs()));
    }

    #[test]
    fn projection_stays_inside_and_is_identity_inside() {
        let d = 6;
        let k = 2;
        let (u, w, _) = random_factored(d, k, 0.7, 78);
        let fac = FactoredPsd { u: &u, w: &w, shift: 0.7, active: k };
        let mut rng = Pcg64::new(79);
        // Inside: unchanged.
        let small: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 0.01 * x).collect();
        let p = fac.project_ball(&small, 1.0);
        for i in 0..d {
            assert_eq!(p[i], small[i]);
        }
        // Outside: lands on the boundary.
        let big: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 10.0 * x).collect();
        let p = fac.project_ball(&big, 1.0);
        let n = crate::tensor::norm2(&p);
        assert!(n <= 1.0 + 1e-9 && n > 0.99, "‖p‖ = {n}");
    }

    #[test]
    fn projection_optimality_kkt() {
        // Check the projection beats random feasible points in M-norm.
        let d = 5;
        let k = 2;
        let shift = 0.4;
        let (u, w, dense) = random_factored(d, k, shift, 80);
        let fac = FactoredPsd { u: &u, w: &w, shift, active: k };
        let e = eigh(&dense);
        let m_half = e.apply_spectral(|lam| lam.max(0.0).sqrt());
        let mnorm2 = |v: &[f64]| {
            let mv = matvec(&m_half, v);
            crate::tensor::dot(v, &mv)
        };
        let mut rng = Pcg64::new(81);
        let y: Vec<f64> = rng.gaussian_vec(d).iter().map(|x| 3.0 * x).collect();
        let p = fac.project_ball(&y, 1.0);
        let diff_p: Vec<f64> = (0..d).map(|i| p[i] - y[i]).collect();
        let obj_p = mnorm2(&diff_p);
        for _ in 0..50 {
            let mut z = rng.gaussian_vec(d);
            let zn = crate::tensor::norm2(&z);
            let r = rng.uniform();
            for v in &mut z {
                *v *= r / zn;
            }
            let diff_z: Vec<f64> = (0..d).map(|i| z[i] - y[i]).collect();
            assert!(
                obj_p <= mnorm2(&diff_z) + 1e-9,
                "projection not optimal: {obj_p} vs {}",
                mnorm2(&diff_z)
            );
        }
    }
}
