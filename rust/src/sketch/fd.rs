//! Frequent Directions sketch — Algorithm 1 of the paper, in factored form.
//!
//! The sketch tracks a rank-ℓ approximation `Ḡ_t ≈ Σ_s M_s` of a stream of
//! PSD updates without ever materializing the d×d covariance. Internally
//! we store the eigendecomposition `Ḡ = U diag(w) Uᵀ` (U: d×ℓ orthonormal,
//! w descending with the ℓ-th entry always 0 — the Alg. 1 invariant that
//! the last column of B is 0), which is exactly what the preconditioner
//! applications need.
//!
//! An update with news `Y Yᵀ` (Y: d×r) forms the augmented factor
//! `A = [U diag(√(β₂ w)) | Y]` and eigendecomposes the (ℓ+r)×(ℓ+r) Gram
//! matrix AᵀA — never a d×d matrix — then deflates by the ℓ-th eigenvalue
//! λ_ℓ, accumulating the escaped mass ρ_{1:t} = Σ_t λ_ℓ^{(t)}. This is the
//! same complexity class as the paper's SVD-of-[√β₂B; G] implementation
//! (§6) at O(d(ℓ+r)² + (ℓ+r)³) per update.
//!
//! With `decay = β₂ < 1` this is the exponentially-weighted FD of
//! Observation 6; with `decay = 1` it is the classic sketch of Alg. 1 and
//! satisfies Lemma 1 (tested in `dense_ref.rs` property tests).

use crate::tensor::{at_a, eigh, matmul, Matrix};

/// Factored Frequent Directions sketch of a PSD stream.
#[derive(Clone, Debug)]
pub struct FdSketch {
    /// Ambient dimension d.
    d: usize,
    /// Sketch size ℓ (number of tracked directions; the ℓ-th eigenvalue is
    /// always 0 after an update, per Alg. 1).
    ell: usize,
    /// Orthonormal eigenbasis of the sketch, d×ℓ. Columns beyond the
    /// active rank are zero.
    u: Matrix,
    /// Eigenvalues of Ḡ, descending, length ℓ; trailing entries 0.
    w: Vec<f64>,
    /// Exponential decay β₂ applied to the old sketch at each update
    /// (1.0 = unweighted Alg. 1).
    decay: f64,
    /// Cumulative escaped mass ρ_{1:t} = Σ λ_ℓ^{(t)} (with decay, the
    /// running compensation follows the same recursion as the sketch:
    /// ρ̃_t = β₂ ρ̃_{t-1} + λ_ℓ^{(t)}, matching G̃_t = Ḡ_t + ρ̃_t I in the
    /// EMA setting).
    rho_sum: f64,
    /// Escaped mass of the most recent update (λ_ℓ^{(t)}).
    last_rho: f64,
    /// Number of updates performed.
    steps: usize,
}

impl FdSketch {
    /// New empty sketch. `decay=1.0` gives the classic FD of Alg. 1;
    /// `decay=β₂<1` gives the exponentially-weighted variant (Obs. 6).
    pub fn new(d: usize, ell: usize, decay: f64) -> Self {
        assert!(ell >= 1 && ell <= d, "need 1 <= ell <= d (got ell={ell}, d={d})");
        assert!(decay > 0.0 && decay <= 1.0);
        FdSketch {
            d,
            ell,
            u: Matrix::zeros(d, ell),
            w: vec![0.0; ell],
            decay,
            rho_sum: 0.0,
            last_rho: 0.0,
            steps: 0,
        }
    }

    /// Rebuild a sketch from serialized parts (wire / checkpoint restore).
    ///
    /// `u` is the d×ℓ eigenbasis, `w` the ℓ eigenvalues; `decay`,
    /// `rho_sum`, `last_rho` and `steps` restore the EMA/escaped-mass
    /// bookkeeping. Shape and range invariants are validated (the caller
    /// has already bounded allocations at decode time); the value
    /// contents are restored bit-for-bit so a snapshot/restore round
    /// trip is exact.
    pub fn from_parts(
        u: Matrix,
        w: Vec<f64>,
        decay: f64,
        rho_sum: f64,
        last_rho: f64,
        steps: usize,
    ) -> anyhow::Result<Self> {
        let d = u.rows();
        let ell = u.cols();
        anyhow::ensure!(
            ell >= 1 && ell <= d,
            "sketch restore: need 1 <= ell <= d (got ell={ell}, d={d})"
        );
        anyhow::ensure!(
            decay > 0.0 && decay <= 1.0,
            "sketch restore: decay {decay} outside (0, 1]"
        );
        anyhow::ensure!(
            w.len() == ell,
            "sketch restore: {} eigenvalues for rank-{ell} sketch",
            w.len()
        );
        Ok(FdSketch { d, ell, u, w, decay, rho_sum, last_rho, steps })
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Exponential decay β₂ applied at each update (1.0 = unweighted).
    #[inline]
    pub fn decay(&self) -> f64 {
        self.decay
    }

    #[inline]
    pub fn rank(&self) -> usize {
        self.ell
    }

    /// Eigenvalues of the current sketch Ḡ (descending, length ℓ).
    #[inline]
    pub fn eigenvalues(&self) -> &[f64] {
        &self.w
    }

    /// Orthonormal eigenbasis (d×ℓ; zero columns beyond the active rank).
    #[inline]
    pub fn basis(&self) -> &Matrix {
        &self.u
    }

    /// Cumulative escaped mass ρ_{1:t}.
    #[inline]
    pub fn escaped_mass(&self) -> f64 {
        self.rho_sum
    }

    /// Escaped mass of the last update, λ_ℓ^{(t)}.
    #[inline]
    pub fn last_escaped(&self) -> f64 {
        self.last_rho
    }

    #[inline]
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Number of strictly positive eigenvalues.
    pub fn active_rank(&self) -> usize {
        self.w.iter().take_while(|&&x| x > 0.0).count()
    }

    /// Update with news `g gᵀ` (the AdaGrad stream of Alg. 2).
    pub fn update_vec(&mut self, g: &[f64]) -> f64 {
        assert_eq!(g.len(), self.d);
        let y = Matrix::from_vec(self.d, 1, g.to_vec());
        self.update(&y)
    }

    /// Update with news `Y Yᵀ` (Y: d×r — for Shampoo, Y = G or Gᵀ).
    /// Returns the escaped mass ρ_t of this update.
    ///
    /// Wide news (r ≫ ℓ) is folded in column chunks of ≤ 2ℓ: FD composes
    /// sequentially (sketching [Y₁ Y₂] equals sketching Y₁ then Y₂ with
    /// no decay on the second), and chunking turns one O(d(ℓ+r)² +
    /// (ℓ+r)³) update into r/2ℓ updates of O(d(3ℓ)² + (3ℓ)³) — ~5x
    /// faster at the LM hot-path shape (EXPERIMENTS.md §Perf). The
    /// result is a valid FD sketch with the same Lemma-1 guarantee
    /// (slightly *more* deflation than the unchunked update, never less
    /// accuracy than the bound).
    pub fn update(&mut self, y: &Matrix) -> f64 {
        assert_eq!(y.rows(), self.d, "news row dim mismatch");
        let chunk = (2 * self.ell).max(8);
        if y.cols() > chunk {
            let mut rho_total = 0.0;
            let mut first = true;
            let mut c0 = 0;
            while c0 < y.cols() {
                let c1 = (c0 + chunk).min(y.cols());
                let block = y.slice(0, self.d, c0, c1);
                let decay = if first { self.decay } else { 1.0 };
                rho_total += self.update_inner(&block, decay);
                first = false;
                c0 = c1;
            }
            self.steps += 1;
            self.last_rho = rho_total;
            return rho_total;
        }
        let rho = self.update_inner(y, self.decay);
        self.steps += 1;
        self.last_rho = rho;
        rho
    }

    /// One FD update with an explicit decay on the existing sketch.
    fn update_inner(&mut self, y: &Matrix, decay: f64) -> f64 {
        let r = y.cols();
        let k = self.active_rank();
        // Augmented factor A = [U diag(sqrt(decay * w)) | Y]  (d × (k+r)).
        let m = k + r;
        let mut a = Matrix::zeros(self.d, m);
        for j in 0..k {
            let s = (decay * self.w[j]).sqrt();
            for i in 0..self.d {
                a[(i, j)] = self.u[(i, j)] * s;
            }
        }
        a.set_slice(0, k, y);
        // Small Gram eigendecomposition: AᵀA = V diag(λ) Vᵀ, so
        // AAᵀ = (A V Σ⁻¹) diag(λ) (A V Σ⁻¹)ᵀ shares the nonzero spectrum.
        let gram = at_a(&a);
        let e = eigh(&gram);
        let lam: Vec<f64> = e.w.iter().map(|&x| x.max(0.0)).collect();
        // Deflation value: the ℓ-th eigenvalue (1-indexed) of the updated
        // covariance, 0 if the spectrum is shorter than ℓ.
        let rho = if m >= self.ell { lam[self.ell - 1] } else { 0.0 };
        // New eigenbasis: u_i = A v_i / σ_i for the kept directions.
        let keep = self.ell.min(m);
        let av = matmul(&a, &e.q); // d × m, column i = A v_i = σ_i u_i
        let mut new_u = Matrix::zeros(self.d, self.ell);
        let mut new_w = vec![0.0; self.ell];
        for j in 0..keep {
            let wj = (lam[j] - rho).max(0.0);
            let sigma = lam[j].sqrt();
            if wj > 0.0 && sigma > 1e-300 {
                new_w[j] = wj;
                for i in 0..self.d {
                    new_u[(i, j)] = av[(i, j)] / sigma;
                }
            }
        }
        self.u = new_u;
        self.w = new_w;
        // Escaped-mass compensation follows the sketch's own recursion so
        // that G̃_t = Ḡ_t + ρ̃_t I remains the Alg. 2 preconditioner in both
        // the unweighted (decay=1: plain sum) and EMA settings.
        self.rho_sum = decay * self.rho_sum + rho;
        rho
    }

    /// Materialize Ḡ = U diag(w) Uᵀ (d×d — tests and tiny-d baselines only).
    pub fn materialize(&self) -> Matrix {
        let mut scaled = self.u.clone();
        for j in 0..self.ell {
            for i in 0..self.d {
                scaled[(i, j)] *= self.w[j];
            }
        }
        crate::tensor::a_bt(&scaled, &self.u)
    }

    /// Heap bytes held by the sketch (Fig. 1 memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.u.mem_bytes() + self.w.capacity() * std::mem::size_of::<f64>()
    }

    /// The compensated preconditioner G̃ = Ḡ + ρ_{1:t}·I as a factored PSD
    /// operator (never materialized).
    pub fn compensated(&self) -> super::factored::FactoredPsd<'_> {
        super::factored::FactoredPsd {
            u: &self.u,
            w: &self.w,
            shift: self.rho_sum,
            active: self.active_rank(),
        }
    }

    /// Like [`Self::compensated`] but with an extra diagonal shift (the
    /// δ-regularization of Ada-FD / FD-SON, or RFD's ρ/2 correction).
    pub fn shifted(&self, extra: f64) -> super::factored::FactoredPsd<'_> {
        super::factored::FactoredPsd {
            u: &self.u,
            w: &self.w,
            shift: extra,
            active: self.active_rank(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::at_a as gram;
    use crate::util::rng::Pcg64;

    #[test]
    fn first_update_captures_rank1_exactly() {
        let mut fd = FdSketch::new(8, 4, 1.0);
        let g = vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, -1.0];
        let rho = fd.update_vec(&g);
        // Rank-1 news with ell>1: nothing escapes.
        assert_eq!(rho, 0.0);
        let m = fd.materialize();
        let expected = crate::tensor::outer(&g, &g);
        assert!(m.max_diff(&expected) < 1e-10);
    }

    #[test]
    fn exact_while_under_capacity() {
        // Stream of rank-1 updates from a (ell-1)-dim subspace: FD is exact.
        let mut rng = Pcg64::new(60);
        let d = 10;
        let ell = 5;
        let dirs = crate::tensor::random_orthonormal(d, ell - 1, &mut rng);
        let mut fd = FdSketch::new(d, ell, 1.0);
        let mut exact = Matrix::zeros(d, d);
        for _ in 0..20 {
            let c: Vec<f64> = (0..ell - 1).map(|_| rng.gaussian()).collect();
            let g: Vec<f64> = (0..d)
                .map(|i| (0..ell - 1).map(|j| dirs[(i, j)] * c[j]).sum())
                .collect();
            fd.update_vec(&g);
            exact = exact.add(&crate::tensor::outer(&g, &g));
        }
        assert!(fd.escaped_mass() < 1e-9);
        assert!(fd.materialize().max_diff(&exact) < 1e-7 * (1.0 + exact.max_abs()));
    }

    #[test]
    fn invariant_last_eigenvalue_zero() {
        let mut rng = Pcg64::new(61);
        let mut fd = FdSketch::new(12, 4, 1.0);
        for _ in 0..30 {
            let g = rng.gaussian_vec(12);
            fd.update_vec(&g);
            // Alg. 1 invariant: after deflation the ℓ-th eigenvalue is 0.
            assert_eq!(fd.eigenvalues()[3], 0.0);
            assert!(fd.active_rank() <= 3);
        }
        assert!(fd.escaped_mass() > 0.0);
    }

    #[test]
    fn eigenvalues_descending_and_basis_orthonormal() {
        let mut rng = Pcg64::new(62);
        let mut fd = FdSketch::new(16, 6, 1.0);
        for _ in 0..25 {
            let g = rng.gaussian_vec(16);
            fd.update_vec(&g);
        }
        let w = fd.eigenvalues();
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
        let k = fd.active_rank();
        let ub = fd.basis().slice(0, 16, 0, k);
        let qtq = gram(&ub);
        assert!(qtq.max_diff(&Matrix::eye(k)) < 1e-8);
    }

    #[test]
    fn matrix_news_matches_vector_stream() {
        // One update with Y (d×3) == three rank-1 updates in exact regime
        // (under capacity the sketch is exact, so order doesn't matter).
        let mut rng = Pcg64::new(63);
        let d = 9;
        let y = Matrix::randn(d, 3, &mut rng);
        let mut fd_mat = FdSketch::new(d, 8, 1.0);
        fd_mat.update(&y);
        let mut fd_vec = FdSketch::new(d, 8, 1.0);
        for j in 0..3 {
            fd_vec.update_vec(&y.col(j));
        }
        assert!(fd_mat.materialize().max_diff(&fd_vec.materialize()) < 1e-8);
    }

    #[test]
    fn decay_shrinks_old_mass() {
        let mut fd = FdSketch::new(4, 3, 0.5);
        fd.update_vec(&[2.0, 0.0, 0.0, 0.0]); // Ḡ = diag(4,0,0,0)
        fd.update_vec(&[0.0, 1.0, 0.0, 0.0]); // Ḡ = diag(2,1,0,0)
        let m = fd.materialize();
        assert!((m[(0, 0)] - 2.0).abs() < 1e-10);
        assert!((m[(1, 1)] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn escaped_mass_lemma1_bound() {
        // Lemma 1: rho_{1:T} <= sum_{i=ell}^d lambda_i(G_T)  (decay = 1).
        let mut rng = Pcg64::new(64);
        let d = 10;
        let t = 40;
        for ell in [2usize, 4, 7] {
            let mut fd = FdSketch::new(d, ell, 1.0);
            let mut gmat = Matrix::zeros(t, d);
            let mut rng2 = rng.split();
            for s in 0..t {
                // Anisotropic stream for a decaying spectrum.
                let g: Vec<f64> = (0..d)
                    .map(|i| rng2.gaussian() / (1.0 + i as f64))
                    .collect();
                fd.update_vec(&g);
                gmat.row_mut(s).copy_from_slice(&g);
            }
            let cov = gram(&gmat);
            let eig = crate::tensor::eigh(&cov);
            let tail: f64 = eig.w[ell - 1..].iter().sum();
            assert!(
                fd.escaped_mass() <= tail + 1e-8,
                "ell={ell}: rho={} > tail={tail}",
                fd.escaped_mass()
            );
        }
    }

    #[test]
    fn sketch_lower_bounds_true_covariance() {
        // Remark 11: Ḡ ⪯ G ⪯ Ḡ + ρI (check via eigenvalues of differences).
        let mut rng = Pcg64::new(65);
        let d = 8;
        let ell = 3;
        let mut fd = FdSketch::new(d, ell, 1.0);
        let mut exact = Matrix::zeros(d, d);
        for _ in 0..25 {
            let g = rng.gaussian_vec(d);
            fd.update_vec(&g);
            exact = exact.add(&crate::tensor::outer(&g, &g));
        }
        let bar = fd.materialize();
        let lower_gap = crate::tensor::eigh(&exact.sub(&bar));
        assert!(
            lower_gap.w.iter().all(|&x| x > -1e-8),
            "Ḡ ⋠ G: min eig {:?}",
            lower_gap.w.last()
        );
        let mut upper = bar.clone();
        upper.add_diag(fd.escaped_mass());
        let upper_gap = crate::tensor::eigh(&upper.sub(&exact));
        assert!(
            upper_gap.w.iter().all(|&x| x > -1e-8),
            "G ⋠ Ḡ + ρI: min eig {:?}",
            upper_gap.w.last()
        );
    }

    #[test]
    fn chunked_wide_news_matches_sequential_updates() {
        // Wide news (r > 2ℓ) takes the chunked path; it must equal the
        // sequential narrow-chunk composition exactly, and stay a valid
        // sketch (Lemma 1-style dominance checked via escaped mass).
        let mut rng = Pcg64::new(66);
        let d = 20;
        let ell = 3;
        let y = Matrix::randn(d, 17, &mut rng); // 17 > 2*3 → chunked
        let mut fd_wide = FdSketch::new(d, ell, 0.9);
        fd_wide.update(&y);
        let mut fd_seq = FdSketch::new(d, ell, 0.9);
        let chunk = (2 * ell).max(8); // must match update()'s chunking
        let mut c0 = 0;
        let mut first = true;
        while c0 < 17 {
            let c1 = (c0 + chunk).min(17);
            let block = y.slice(0, d, c0, c1);
            if first {
                fd_seq.update(&block);
                first = false;
            } else {
                // No decay between chunks of one logical update.
                let mut tmp = FdSketch::new(d, ell, 1.0);
                tmp.u = fd_seq.u.clone();
                tmp.w = fd_seq.w.clone();
                tmp.rho_sum = fd_seq.rho_sum;
                tmp.update(&block);
                fd_seq.u = tmp.u;
                fd_seq.w = tmp.w;
                fd_seq.rho_sum = tmp.rho_sum;
            }
            c0 = c1;
        }
        assert!(
            fd_wide.materialize().max_diff(&fd_seq.materialize()) < 1e-8,
            "chunked path diverged from sequential composition"
        );
        assert!((fd_wide.escaped_mass() - fd_seq.escaped_mass()).abs() < 1e-8);
    }

    #[test]
    fn from_parts_roundtrips_bitwise_and_validates() {
        let mut rng = Pcg64::new(67);
        let mut fd = FdSketch::new(14, 5, 0.97);
        for _ in 0..12 {
            let g = rng.gaussian_vec(14);
            fd.update_vec(&g);
        }
        let restored = FdSketch::from_parts(
            fd.basis().clone(),
            fd.eigenvalues().to_vec(),
            fd.decay(),
            fd.escaped_mass(),
            fd.last_escaped(),
            fd.steps(),
        )
        .unwrap();
        assert_eq!(restored.dim(), 14);
        assert_eq!(restored.rank(), 5);
        assert_eq!(restored.escaped_mass().to_bits(), fd.escaped_mass().to_bits());
        assert_eq!(restored.steps(), fd.steps());
        for (a, b) in restored.eigenvalues().iter().zip(fd.eigenvalues()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(restored.basis().max_diff(fd.basis()), 0.0);
        // A further update evolves both copies identically.
        let g = rng.gaussian_vec(14);
        let mut fd2 = restored;
        fd.update_vec(&g);
        fd2.update_vec(&g);
        assert_eq!(fd.materialize().max_diff(&fd2.materialize()), 0.0);
        assert_eq!(fd.escaped_mass().to_bits(), fd2.escaped_mass().to_bits());
        // Invalid parts are refused.
        assert!(FdSketch::from_parts(Matrix::zeros(4, 5), vec![0.0; 5], 1.0, 0.0, 0.0, 0).is_err());
        assert!(FdSketch::from_parts(Matrix::zeros(5, 3), vec![0.0; 2], 1.0, 0.0, 0.0, 0).is_err());
        assert!(FdSketch::from_parts(Matrix::zeros(5, 3), vec![0.0; 3], 0.0, 0.0, 0.0, 0).is_err());
    }

    #[test]
    fn mem_bytes_scales_with_d_ell() {
        let fd_small = FdSketch::new(100, 4, 1.0);
        let fd_big = FdSketch::new(100, 16, 1.0);
        assert!(fd_big.mem_bytes() > 3 * fd_small.mem_bytes());
        // d*ell dominates: 100*16*8 bytes.
        assert!(fd_big.mem_bytes() >= 100 * 16 * 8);
    }
}
