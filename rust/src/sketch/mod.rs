//! Frequent Directions sketch substrate (system S3 in DESIGN.md).
//!
//! - [`fd::FdSketch`] — factored Alg. 1 / Obs. 6 sketch (the paper's core
//!   data structure), O(dℓ) memory, small-Gram updates.
//! - [`factored::FactoredPsd`] — O(dℓ) spectral-function applies and the
//!   ‖·‖_{G̃^{1/2}} ball projection used by Alg. 2.
//! - [`dense_ref::DenseFd`] — the d×d pseudocode-faithful oracle used by
//!   property tests.

pub mod dense_ref;
pub mod factored;
pub mod fd;

pub use factored::FactoredPsd;
pub use fd::FdSketch;
