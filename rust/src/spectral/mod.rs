//! Spectral analysis tooling (system S10) — reproduces §5.2 / Fig. 3.
//!
//! Tracks the exponential moving average of Kronecker-factored gradient
//! covariance `L_t = Σ β₂^{t-i} G_i G_iᵀ` (and R_t) during training and
//! computes the paper's two concentration measures: the top-k spectral
//! mass fraction and the intrinsic dimension `tr C / λ_max(C)`.

use crate::tensor::{a_at, at_a, eigh, Matrix};
use crate::util::rng::Pcg64;

/// EMA tracker for one tensor's Kronecker covariance factors.
pub struct KronTracker {
    pub beta2: f64,
    pub l: Matrix,
    pub r: Matrix,
    steps: usize,
}

impl KronTracker {
    pub fn new(m: usize, n: usize, beta2: f64) -> Self {
        KronTracker { beta2, l: Matrix::zeros(m, m), r: Matrix::zeros(n, n), steps: 0 }
    }

    /// Fold in one gradient: L ← β₂L + GGᵀ, R ← β₂R + GᵀG.
    pub fn update(&mut self, g: &Matrix) {
        self.l.scale_inplace(self.beta2);
        self.l.axpy(1.0, &a_at(g));
        self.r.scale_inplace(self.beta2);
        self.r.axpy(1.0, &at_a(g));
        self.steps += 1;
    }

    pub fn steps(&self) -> usize {
        self.steps
    }
}

/// Intrinsic dimension tr C / λ_max(C) (Vershynin [39] Rem. 5.6.3); the
/// right-hand Fig. 3 measure. λ_max via power iteration (cheap; no full
/// eigh needed).
pub fn intrinsic_dim(c: &Matrix) -> f64 {
    let tr = c.trace();
    let lmax = lambda_max(c, 200, 1e-10);
    if lmax <= 0.0 {
        return 0.0;
    }
    tr / lmax
}

/// Largest eigenvalue of a symmetric PSD matrix by power iteration.
pub fn lambda_max(c: &Matrix, iters: usize, tol: f64) -> f64 {
    let n = c.rows();
    if n == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::new(0x11ec + n as u64);
    let mut v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let mut lam = 0.0;
    for _ in 0..iters {
        let w = crate::tensor::matvec(c, &v);
        let nw = crate::tensor::norm2(&w);
        if nw < 1e-300 {
            return 0.0;
        }
        let new_lam = crate::tensor::dot(&v, &w);
        v = w.iter().map(|x| x / nw).collect();
        if (new_lam - lam).abs() <= tol * (1.0 + new_lam.abs()) {
            return new_lam;
        }
        lam = new_lam;
    }
    lam
}

/// Fraction of spectral mass in the top k eigenvalues:
/// Σ_{i≤k} λ_i / Σ_i λ_i (the left-hand Fig. 3 measure).
pub fn spectral_mass_topk(c: &Matrix, k: usize) -> f64 {
    let e = eigh(c);
    let total: f64 = e.w.iter().map(|&w| w.max(0.0)).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let top: f64 = e.w.iter().take(k).map(|&w| w.max(0.0)).sum();
    top / total
}

/// §5.2's random-matrix control: intrinsic dimension of
/// `Σ_{i<n} β₂ⁱ xᵢxᵢᵀ` with xᵢ iid N(0,1) of shape dim×d. The paper
/// reports 324.63 (d=1) and 862.13 (d=64) at dim=1024, n=10000 — far
/// above the ≈10–105 observed in real training, proving the observed
/// decay is an emergent property of DL training and not an EMA artifact.
pub fn wishart_ema_intrinsic_dim(
    dim: usize,
    d: usize,
    n: usize,
    beta2: f64,
    seed: u64,
) -> f64 {
    let mut rng = Pcg64::new(seed);
    let mut c = Matrix::zeros(dim, dim);
    for _ in 0..n {
        let x = Matrix::randn(dim, d, &mut rng);
        c.scale_inplace(beta2);
        c.axpy(1.0, &a_at(&x));
    }
    intrinsic_dim(&c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_max_matches_eigh() {
        let mut rng = Pcg64::new(300);
        let g = Matrix::randn(20, 9, &mut rng);
        let c = at_a(&g);
        let pm = lambda_max(&c, 500, 1e-12);
        let ev = eigh(&c).w[0];
        assert!((pm - ev).abs() < 1e-6 * (1.0 + ev));
    }

    #[test]
    fn intrinsic_dim_extremes() {
        // Identity: intrinsic dim = n. Rank-1: intrinsic dim = 1.
        let i = Matrix::eye(12);
        assert!((intrinsic_dim(&i) - 12.0).abs() < 1e-6);
        let u: Vec<f64> = (0..12).map(|i| (i as f64 + 1.0).sin()).collect();
        let r1 = crate::tensor::outer(&u, &u);
        assert!((intrinsic_dim(&r1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topk_mass_monotone_and_bounded() {
        let mut rng = Pcg64::new(301);
        let g = Matrix::randn(30, 10, &mut rng);
        let c = at_a(&g);
        let mut prev = 0.0;
        for k in 1..=10 {
            let m = spectral_mass_topk(&c, k);
            assert!(m >= prev - 1e-12 && m <= 1.0 + 1e-12);
            prev = m;
        }
        assert!((prev - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tracker_accumulates_ema() {
        let mut t = KronTracker::new(3, 2, 0.5);
        let g1 = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 0.0], vec![0.0, 0.0]]);
        t.update(&g1);
        t.update(&g1);
        // L = 0.5·g1g1ᵀ + g1g1ᵀ = 1.5 at (0,0).
        assert!((t.l[(0, 0)] - 1.5).abs() < 1e-12);
        assert!((t.r[(0, 0)] - 1.5).abs() < 1e-12);
        assert_eq!(t.steps(), 2);
    }

    #[test]
    fn wishart_control_small_scale() {
        // Scaled-down version of the §5.2 experiment: EMA of Wisharts at
        // dim=64. With β₂=0.9 the effective sample count ≈ 10, so d=1
        // gives intrinsic dim ≈ 10 ≪ 64, d=64 pushes toward ~45-64.
        let id1 = wishart_ema_intrinsic_dim(64, 1, 200, 0.9, 40);
        let id64 = wishart_ema_intrinsic_dim(64, 64, 200, 0.9, 41);
        assert!(id1 < id64, "intrinsic dim should grow with d: {id1} vs {id64}");
        assert!(id1 > 2.0 && id1 < 40.0, "id1={id1}");
        assert!(id64 > 30.0, "id64={id64}");
    }
}
