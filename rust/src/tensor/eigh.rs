//! Symmetric eigendecomposition.
//!
//! Every eigendecomposition in this system runs here: the PJRT boundary
//! cannot carry LAPACK custom calls (xla_extension 0.5.1 predates jax's
//! typed-FFI lowering — see DESIGN.md §1), so the FD sketch updates and
//! Shampoo inverse roots decompose on the Rust side.
//!
//! Two algorithms:
//! - [`eigh`] — Householder tridiagonalization (tred2) + implicit-shift QL
//!   with eigenvector accumulation (tql2). O(n³) with a small constant;
//!   handles the ≤ a-few-thousand dimensional blocks this system uses.
//! - [`eigh_jacobi`] — cyclic Jacobi. Slower but independently derived;
//!   used as a cross-check oracle in tests and for tiny matrices.
//!
//! Both return eigenvalues in **descending** order (the FD convention of
//! Alg. 1 in the paper: λ₁ ≥ λ₂ ≥ …) with eigenvectors as columns of `q`
//! such that `a = q · diag(w) · qᵀ`.

use super::matrix::Matrix;

/// Result of a symmetric eigendecomposition: `a = q·diag(w)·qᵀ`,
/// eigenvalues descending.
#[derive(Clone, Debug)]
pub struct Eigh {
    /// Eigenvalues, descending.
    pub w: Vec<f64>,
    /// Orthonormal eigenvectors, column i pairs with w[i].
    pub q: Matrix,
}

/// Symmetric eigendecomposition via tridiagonalization + implicit QL.
///
/// Panics if `a` is not square; asymmetry is tolerated (only the lower
/// triangle is read after the initial symmetrization copy).
pub fn eigh(a: &Matrix) -> Eigh {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh requires a square matrix");
    if n == 0 {
        return Eigh { w: vec![], q: Matrix::zeros(0, 0) };
    }
    if n == 1 {
        return Eigh { w: vec![a[(0, 0)]], q: Matrix::eye(1) };
    }
    // Work on a symmetrized copy.
    let mut z = a.clone();
    z.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut z, &mut d, &mut e);
    // QL rotations update eigenvector *columns*; in row-major storage
    // that is a strided walk. Accumulate in the transpose so each Givens
    // rotation is two contiguous-row AXPYs (measured ~4x on n=512 —
    // EXPERIMENTS.md §Perf), then transpose back.
    let mut zt = z.t();
    tql2(&mut zt, &mut d, &mut e);
    let mut z = zt.t();
    sort_descending(&mut d, &mut z);
    Eigh { w: d, q: z }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (EISPACK tred2). On exit `z` holds the orthogonal transformation, `d`
/// the diagonal, `e` the subdiagonal (e[0] unused).
fn tred2(z: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                let mut f_acc = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f_acc += e[j] * z[(i, j)];
                }
                let hh = f_acc / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let gj = e[j] - hh * f;
                    e[j] = gj;
                    for k in 0..=j {
                        let delta = f * e[k] + gj * z[(i, k)];
                        z[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    // Accumulate transformation. The textbook loop walks columns of z
    // (strided in row-major); we block it as G = Z[0..i]ᵀ u then a rank-1
    // row-major update Z[0..i] -= v Gᵀ, keeping every inner loop
    // contiguous (~35% on n=512, EXPERIMENTS.md §Perf).
    let mut gbuf = vec![0.0; n];
    for i in 0..n {
        if d[i] != 0.0 {
            // g[j] = Σ_k z[i][k] · z[k][j] for j < i (gᵀ = uᵀ Z[0..i]).
            gbuf[..i].fill(0.0);
            for k in 0..i {
                let uik = z[(i, k)];
                if uik == 0.0 {
                    continue;
                }
                let row_k = &z.row(k)[..i];
                // Contiguous fused-multiply-add over row k.
                for (gj, &zkj) in gbuf[..i].iter_mut().zip(row_k) {
                    *gj += uik * zkj;
                }
            }
            // z[k][j] -= g[j] · z[k][i] — row-major rank-1 update.
            for k in 0..i {
                let vki = z[(k, i)];
                if vki == 0.0 {
                    continue;
                }
                let row_k = z.row_mut(k);
                for (zkj, &gj) in row_k[..i].iter_mut().zip(&gbuf[..i]) {
                    *zkj -= gj * vki;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

#[inline]
fn hypot(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Implicit-shift QL on a tridiagonal matrix with eigenvector
/// accumulation (EISPACK tql2). `d` = diagonal in, eigenvalues out;
/// `e` = subdiagonal (e[0] unused); `zt` = accumulated transform in,
/// eigenvectors out — **stored transposed** (row i of `zt` is
/// eigenvector i) so the inner rotation loop is contiguous.
fn tql2(zt: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    // Absolute deflation floor: matrices fed by optimizer statistics can
    // span ~16 orders of magnitude; a subdiagonal entry this far below
    // the matrix norm is numerically zero even when its neighbors are.
    let anorm = d
        .iter()
        .chain(e.iter())
        .fold(0.0f64, |a, &x| a.max(x.abs()));
    let floor = f64::EPSILON * anorm.max(f64::MIN_POSITIVE);
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small subdiagonal element.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd || e[m].abs() <= floor {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 128 {
                // Force deflation rather than panicking: the residual
                // subdiagonal is O(eps·‖A‖) noise at this point and the
                // FD/Shampoo consumers are robust to it.
                e[m.min(n - 1)] = 0.0;
                e[l] = 0.0;
                break;
            }
            // Form shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = hypot(g, 1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = hypot(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    // Recover from underflow.
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate eigenvectors: rotate transposed rows i, i+1
                // (contiguous; auto-vectorizes).
                {
                    let (lo, hi) = zt.as_mut_slice().split_at_mut((i + 1) * n);
                    let row_i = &mut lo[i * n..(i + 1) * n];
                    let row_i1 = &mut hi[..n];
                    for k in 0..n {
                        let f = row_i1[k];
                        row_i1[k] = s * row_i[k] + c * f;
                        row_i[k] = c * row_i[k] - s * f;
                    }
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Sort eigenvalues descending, permuting eigenvector columns to match.
fn sort_descending(d: &mut [f64], z: &mut Matrix) {
    let n = d.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let d_old = d.to_vec();
    let z_old = z.clone();
    for (new_col, &old_col) in idx.iter().enumerate() {
        d[new_col] = d_old[old_col];
        for r in 0..n {
            z[(r, new_col)] = z_old[(r, old_col)];
        }
    }
}

/// Cyclic Jacobi eigendecomposition — independent implementation used as a
/// test oracle and for very small matrices where its simplicity wins.
pub fn eigh_jacobi(a: &Matrix) -> Eigh {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut m = a.clone();
    m.symmetrize();
    let mut q = Matrix::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apq = m[(p, r)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(r, r)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and r.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, r)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, r)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(r, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(r, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkq = q[(k, r)];
                    q[(k, p)] = c * qkp - s * qkq;
                    q[(k, r)] = s * qkp + c * qkq;
                }
            }
        }
    }
    let mut d: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    sort_descending(&mut d, &mut q);
    Eigh { w: d, q }
}

impl Eigh {
    /// Reconstruct q · diag(w) · qᵀ (test helper; O(n³)).
    pub fn reconstruct(&self) -> Matrix {
        let n = self.w.len();
        let mut scaled = self.q.clone();
        for j in 0..n {
            for i in 0..n {
                scaled[(i, j)] *= self.w[j];
            }
        }
        super::ops::a_bt(&scaled, &self.q)
    }

    /// Apply f to the spectrum: q · diag(f(w)) · qᵀ.
    pub fn apply_spectral(&self, f: impl Fn(f64) -> f64) -> Matrix {
        let n = self.w.len();
        let mut scaled = self.q.clone();
        for j in 0..n {
            let fv = f(self.w[j]);
            for i in 0..n {
                scaled[(i, j)] *= fv;
            }
        }
        super::ops::a_bt(&scaled, &self.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{at_a, matmul};
    use crate::util::rng::Pcg64;

    fn check_decomposition(a: &Matrix, eig: &Eigh, tol: f64) {
        let n = a.rows();
        // Descending order.
        for i in 1..n {
            assert!(
                eig.w[i - 1] >= eig.w[i] - 1e-12,
                "not descending: {:?}",
                eig.w
            );
        }
        // Orthonormal columns.
        let qtq = at_a(&eig.q);
        assert!(
            qtq.max_diff(&Matrix::eye(n)) < tol,
            "q not orthonormal: {}",
            qtq.max_diff(&Matrix::eye(n))
        );
        // Reconstruction.
        let recon = eig.reconstruct();
        let mut sym = a.clone();
        sym.symmetrize();
        assert!(
            recon.max_diff(&sym) < tol * (1.0 + sym.max_abs()),
            "reconstruction error {}",
            recon.max_diff(&sym)
        );
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::diag(&[3.0, -1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.w[0] - 3.0).abs() < 1e-12);
        assert!((e.w[1] - 2.0).abs() < 1e-12);
        assert!((e.w[2] + 1.0).abs() < 1e-12);
        check_decomposition(&a, &e, 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = eigh(&a);
        assert!((e.w[0] - 3.0).abs() < 1e-12);
        assert!((e.w[1] - 1.0).abs() < 1e-12);
        check_decomposition(&a, &e, 1e-12);
    }

    #[test]
    fn random_symmetric_various_sizes() {
        let mut rng = Pcg64::new(10);
        for &n in &[2usize, 3, 5, 8, 16, 33, 64, 100] {
            let b = Matrix::randn(n, n, &mut rng);
            let mut a = b.add(&b.t());
            a.scale_inplace(0.5);
            let e = eigh(&a);
            check_decomposition(&a, &e, 1e-8);
            // Trace and Frobenius preserved by spectrum.
            let tr: f64 = e.w.iter().sum();
            assert!((tr - a.trace()).abs() < 1e-8 * (1.0 + a.trace().abs()));
            let fro2: f64 = e.w.iter().map(|x| x * x).sum();
            let afro2 = a.fro_norm().powi(2);
            assert!((fro2 - afro2).abs() < 1e-6 * (1.0 + afro2));
        }
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        let mut rng = Pcg64::new(11);
        let g = Matrix::randn(40, 12, &mut rng);
        let a = at_a(&g);
        let e = eigh(&a);
        for &w in &e.w {
            assert!(w > -1e-9, "negative eigenvalue {w} for PSD input");
        }
        check_decomposition(&a, &e, 1e-8);
    }

    #[test]
    fn rank_deficient_spectrum() {
        let mut rng = Pcg64::new(12);
        // Rank-3 PSD matrix in dimension 10.
        let g = Matrix::randn(3, 10, &mut rng);
        let a = at_a(&g);
        let e = eigh(&a);
        for &w in &e.w[3..] {
            assert!(w.abs() < 1e-8, "rank-deficient tail not ~0: {:?}", e.w);
        }
        check_decomposition(&a, &e, 1e-8);
    }

    #[test]
    fn degenerate_eigenvalues() {
        // 2*I plus a rank-1 bump: eigenvalues {3, 2, 2, 2}.
        let n = 4;
        let mut a = Matrix::eye(n);
        a.scale_inplace(2.0);
        let u = [0.5, 0.5, 0.5, 0.5];
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] += u[i] * u[j];
            }
        }
        let e = eigh(&a);
        assert!((e.w[0] - 3.0).abs() < 1e-10);
        for &w in &e.w[1..] {
            assert!((w - 2.0).abs() < 1e-10);
        }
        check_decomposition(&a, &e, 1e-10);
    }

    #[test]
    fn matches_jacobi_oracle() {
        let mut rng = Pcg64::new(13);
        for &n in &[4usize, 9, 21] {
            let b = Matrix::randn(n, n, &mut rng);
            let a = b.add(&b.t()).scale(0.5);
            let e1 = eigh(&a);
            let e2 = eigh_jacobi(&a);
            for i in 0..n {
                assert!(
                    (e1.w[i] - e2.w[i]).abs() < 1e-8 * (1.0 + e1.w[i].abs()),
                    "eigenvalue mismatch at {i}: {} vs {}",
                    e1.w[i],
                    e2.w[i]
                );
            }
            check_decomposition(&a, &e2, 1e-8);
        }
    }

    #[test]
    fn apply_spectral_inverse_sqrt() {
        let mut rng = Pcg64::new(14);
        let g = Matrix::randn(30, 6, &mut rng);
        let mut a = at_a(&g);
        a.add_diag(0.5); // strictly PD
        let e = eigh(&a);
        let inv_sqrt = e.apply_spectral(|w| 1.0 / w.sqrt());
        // inv_sqrt * a * inv_sqrt == I
        let prod = matmul(&matmul(&inv_sqrt, &a), &inv_sqrt);
        assert!(prod.max_diff(&Matrix::eye(6)) < 1e-8);
    }

    #[test]
    fn size_one_and_empty() {
        let e = eigh(&Matrix::from_rows(&[vec![7.0]]));
        assert_eq!(e.w, vec![7.0]);
        let e0 = eigh(&Matrix::zeros(0, 0));
        assert!(e0.w.is_empty());
    }
}
