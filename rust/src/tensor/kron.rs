//! Kronecker-product utilities (Lemma 15 of the paper / Gupta et al.).
//!
//! Shampoo's preconditioner is `L ⊗ R` applied implicitly through
//! `(L ⊗ Rᵀ) vec(G) = vec(L G R)`; these helpers exist mostly for tests
//! and the full-matrix baselines, which are the only places a Kronecker
//! product is ever materialized.

use super::matrix::Matrix;
use super::ops::matmul;

/// Materialized Kronecker product `a ⊗ b` (test/baseline use only —
/// O(m²n²) memory, exactly the blow-up the paper's factorization avoids).
pub fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (am, an) = a.shape();
    let (bm, bn) = b.shape();
    let mut out = Matrix::zeros(am * bm, an * bn);
    for i in 0..am {
        for j in 0..an {
            let aij = a[(i, j)];
            if aij == 0.0 {
                continue;
            }
            for p in 0..bm {
                let orow = out.row_mut(i * bm + p);
                let brow = b.row(p);
                for q in 0..bn {
                    orow[j * bn + q] = aij * brow[q];
                }
            }
        }
    }
    out
}

/// Row-major vectorization `vec(G)` (the paper's overline-vec).
pub fn vec_rm(g: &Matrix) -> Vec<f64> {
    g.as_slice().to_vec()
}

/// Inverse of [`vec_rm`].
pub fn unvec_rm(v: &[f64], rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, v.to_vec())
}

/// Implicit Kronecker apply: computes `vec(L · G · R)`, which equals
/// `(L ⊗ Rᵀ) vec(G)` (Lemma 15.7). O(m²n + mn²) instead of O(m²n²).
pub fn kron_apply(l: &Matrix, g: &Matrix, r: &Matrix) -> Matrix {
    matmul(&matmul(l, g), r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matvec;
    use crate::util::rng::Pcg64;

    #[test]
    fn kron_shape_and_values() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 3.0], vec![4.0, 0.0]]);
        let k = kron(&a, &b);
        assert_eq!(k.shape(), (2, 4));
        assert_eq!(k[(0, 1)], 3.0);
        assert_eq!(k[(1, 0)], 4.0);
        assert_eq!(k[(0, 3)], 6.0);
        assert_eq!(k[(1, 2)], 8.0);
    }

    #[test]
    fn kron_mixed_product_property() {
        // (A⊗B)(A'⊗B') = (AA')⊗(BB')  — Lemma 15.1.
        let mut rng = Pcg64::new(50);
        let a = Matrix::randn(2, 3, &mut rng);
        let a2 = Matrix::randn(3, 2, &mut rng);
        let b = Matrix::randn(2, 2, &mut rng);
        let b2 = Matrix::randn(2, 2, &mut rng);
        let lhs = matmul(&kron(&a, &b), &kron(&a2, &b2));
        let rhs = kron(&matmul(&a, &a2), &matmul(&b, &b2));
        assert!(lhs.max_diff(&rhs) < 1e-10);
    }

    #[test]
    fn vec_identity_lemma15_7() {
        // (L ⊗ Rᵀ) vec(G) == vec(L G R) for row-major vec.
        let mut rng = Pcg64::new(51);
        let l = Matrix::randn(3, 3, &mut rng);
        let r = Matrix::randn(4, 4, &mut rng);
        let g = Matrix::randn(3, 4, &mut rng);
        let big = kron(&l, &r.t());
        let lhs = matvec(&big, &vec_rm(&g));
        let rhs = vec_rm(&kron_apply(&l, &g, &r));
        for (x, y) in lhs.iter().zip(&rhs) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn kron_trace_multiplicative() {
        // tr(A⊗B) = tr(A)·tr(B).
        let mut rng = Pcg64::new(52);
        let a = Matrix::randn(3, 3, &mut rng);
        let b = Matrix::randn(2, 2, &mut rng);
        let k = kron(&a, &b);
        assert!((k.trace() - a.trace() * b.trace()).abs() < 1e-10);
    }

    #[test]
    fn unvec_roundtrip() {
        let mut rng = Pcg64::new(53);
        let g = Matrix::randn(5, 7, &mut rng);
        assert_eq!(unvec_rm(&vec_rm(&g), 5, 7), g);
    }
}
