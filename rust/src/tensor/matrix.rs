//! Dense row-major `f64` matrix — the core container of the L3 substrate.
//!
//! Design notes: the optimizer hot loops work on per-layer blocks of at
//! most a few thousand rows/columns, so a simple contiguous row-major
//! buffer with explicit blocked kernels (see [`super::ops`]) is both fast
//! enough and easy to reason about. We deliberately avoid a generic
//! n-dimensional tensor: the paper's algebra is matrices and vectors.

use crate::util::rng::Pcg64;
use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Matrix::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m[(i, i)] = v;
        }
        m
    }

    /// Build from a row-major vec.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows (tests/fixtures convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Build by evaluating f(i, j).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// iid standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.gaussian()).collect(),
        }
    }

    /// Column vector (n×1) from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Matrix { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Underlying row-major slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column j from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transpose (allocates).
    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
    }

    /// Elementwise map (allocates).
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f64) -> f64) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// self *= alpha.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// alpha * self (allocates).
    pub fn scale(&self, alpha: f64) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// self + other.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    /// self - other.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    /// Add alpha to the diagonal in place.
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += alpha;
        }
    }

    /// Sub-matrix copy: rows [r0,r1), cols [c0,c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        self.slice_into(r0, r1, c0, c1, &mut out);
        out
    }

    /// Allocation-free [`Self::slice`]: copy rows [r0,r1) × cols [c0,c1)
    /// into `out`, whose shape must match (the block engine's per-step
    /// gather path).
    pub fn slice_into(&self, r0: usize, r1: usize, c0: usize, c1: usize, out: &mut Matrix) {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        assert_eq!(out.shape(), (r1 - r0, c1 - c0), "slice_into shape mismatch");
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
    }

    /// Paste `block` with top-left corner at (r0, c0).
    pub fn set_slice(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst = &mut self.row_mut(r0 + i)[c0..c0 + block.cols];
            dst.copy_from_slice(block.row(i));
        }
    }

    /// Horizontal concatenation [self | other].
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        out.set_slice(0, 0, self);
        out.set_slice(0, self.cols, other);
        out
    }

    /// Vertical concatenation [self; other].
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut out = Matrix::zeros(self.rows + other.rows, self.cols);
        out.set_slice(0, 0, self);
        out.set_slice(self.rows, 0, other);
        out
    }

    /// Check symmetry to tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetrize in place: (A + Aᵀ)/2 (kills accumulated asymmetry drift).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Max |self - other| entrywise.
    pub fn max_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |a, (x, y)| a.max((x - y).abs()))
    }

    /// Heap bytes used by this matrix (for Fig. 1 memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f64>()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.trace(), 5.0);
        assert!((m.fro_norm() - 30f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn eye_and_diag() {
        let i = Matrix::eye(3);
        assert_eq!(i[(1, 1)], 1.0);
        assert_eq!(i[(0, 1)], 0.0);
        let d = Matrix::diag(&[1.0, 2.0]);
        assert_eq!(d[(1, 1)], 2.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(37, 53, &mut rng);
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t()[(5, 7)], m[(7, 5)]);
    }

    #[test]
    fn slicing_and_concat() {
        let m = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = m.slice(1, 3, 2, 4);
        assert_eq!(s.shape(), (2, 2));
        assert_eq!(s[(0, 0)], 6.0);
        // slice_into reuses an existing buffer and matches slice exactly.
        let mut buf = Matrix::zeros(2, 2);
        m.slice_into(1, 3, 2, 4, &mut buf);
        assert_eq!(buf, s);
        let h = s.hcat(&s);
        assert_eq!(h.shape(), (2, 4));
        let v = s.vcat(&s);
        assert_eq!(v.shape(), (4, 2));
        assert_eq!(v[(2, 0)], 6.0);
    }

    #[test]
    fn axpy_and_scale() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let b = Matrix::from_rows(&[vec![10.0, 20.0]]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.as_slice(), &[6.0, 12.0]);
        assert_eq!(a.scale(2.0).as_slice(), &[2.0, 4.0]);
    }

    #[test]
    fn symmetry_helpers() {
        let mut m = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0 + 1e-12, 3.0]]);
        assert!(m.is_symmetric(1e-9));
        m[(0, 1)] = 5.0;
        assert!(!m.is_symmetric(1e-9));
        m.symmetrize();
        assert!(m.is_symmetric(0.0));
    }
}
