//! Dense linear-algebra substrate (system S1 in DESIGN.md).
//!
//! Everything the optimizer family needs, built from scratch: a row-major
//! [`Matrix`], blocked/threaded matmul kernels ([`ops`]), a symmetric
//! eigensolver ([`eigh`] — tridiagonalization + implicit QL, with a Jacobi
//! cross-check), reduced QR, thin SVD, matrix roots, and Kronecker
//! utilities. The PJRT boundary cannot carry LAPACK custom calls, so this
//! module is the numerical backbone of the whole L3 layer.

pub mod eigh;
pub mod kron;
pub mod matrix;
pub mod ops;
pub mod qr;
pub mod roots;
pub mod svd;

pub use eigh::{eigh, eigh_jacobi, Eigh};
pub use matrix::Matrix;
pub use ops::{a_at, a_bt, at_a, at_b, dot, matmul, matvec, matvec_t, norm2, outer};
pub use qr::{qr, random_orthonormal};
pub use roots::{inv_pth_root, pinv_sqrt, pth_root};
pub use svd::{low_rank_approx, svd, Svd};
