//! Dense kernels: matmul (blocked, multithreaded), Gram products, matvec.
//!
//! These are the L3 hot paths of the optimizer family — an S-Shampoo step
//! is dominated by `at_a` / `a_at` (covariance statistics) and three-way
//! products (preconditioner application). The kernels use i-k-j loop order
//! over row-major storage (unit-stride inner loops the compiler can
//! auto-vectorize) and split work across threads by output row blocks.

use super::matrix::Matrix;
use std::cell::Cell;

thread_local! {
    /// When set, dense kernels on this thread stay single-threaded. The
    /// block engine's workers pin this so per-block math never nests a
    /// second level of threading (oversubscription).
    static SINGLE_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with this thread's dense kernels pinned to one thread
/// (restores the previous setting on exit; results are identical — the
/// kernels' row partition does not change the arithmetic).
pub fn with_single_thread<R>(f: impl FnOnce() -> R) -> R {
    SINGLE_THREAD.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// Number of worker threads for the dense kernels. Resolution order:
/// [`with_single_thread`] pin, `SKETCHY_THREADS` env var, then available
/// parallelism, capped at 16.
pub fn num_threads() -> usize {
    if SINGLE_THREAD.with(|s| s.get()) {
        return 1;
    }
    if let Ok(s) = std::env::var("SKETCHY_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Threshold (in multiply-adds) below which matmul stays single-threaded.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch {:?} x {:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B written into an existing buffer (C is overwritten).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.shape(), (m, n));
    c.as_mut_slice().fill(0.0);
    let flops = m * n * k;
    let threads = num_threads();
    if flops < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        matmul_rows(a, b, c.as_mut_slice(), 0, m);
        return;
    }
    // Partition output rows across threads.
    let chunk = m.div_ceil(threads);
    let n_cols = n;
    let c_data = c.as_mut_slice();
    std::thread::scope(|scope| {
        let mut rest = c_data;
        let mut row0 = 0;
        while row0 < m {
            let rows_here = chunk.min(m - row0);
            let (head, tail) = rest.split_at_mut(rows_here * n_cols);
            rest = tail;
            let r0 = row0;
            scope.spawn(move || {
                matmul_rows_offset(a, b, head, r0, r0 + rows_here);
            });
            row0 += rows_here;
        }
    });
}

/// Compute rows [r0, r1) of A·B into `out` (out is the full C buffer).
fn matmul_rows(a: &Matrix, b: &Matrix, out: &mut [f64], r0: usize, r1: usize) {
    let n = b.cols();
    let sub = &mut out[r0 * n..r1 * n];
    matmul_rows_offset(a, b, sub, r0, r1);
}

/// Compute rows [r0, r1) of A·B into `out`, where out[0..] corresponds to
/// row r0 of C. i-k-j order: for each output row, accumulate scaled rows
/// of B — unit stride everywhere.
fn matmul_rows_offset(a: &Matrix, b: &Matrix, out: &mut [f64], r0: usize, r1: usize) {
    let k = a.cols();
    let n = b.cols();
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        for p in 0..k {
            let aip = arow[p];
            if aip == 0.0 {
                continue;
            }
            let brow = b.row(p);
            // Unit-stride AXPY the compiler vectorizes.
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// C = Aᵀ · B without materializing Aᵀ.
pub fn at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "at_b shape mismatch");
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    // (AᵀB)[i][j] = Σ_p A[p][i] B[p][j]; loop p outermost, rows of A and B
    // both unit stride.
    let c_data = c.as_mut_slice();
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let api = arow[i];
            if api == 0.0 {
                continue;
            }
            let crow = &mut c_data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += api * brow[j];
            }
        }
    }
    c
}

/// C = A · Bᵀ without materializing Bᵀ.
pub fn a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "a_bt shape mismatch");
    let (m, k) = a.shape();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        #[allow(clippy::needless_range_loop)]
        for j in 0..n {
            let brow = b.row(j);
            let mut s = 0.0;
            for p in 0..k {
                s += arow[p] * brow[p];
            }
            crow[j] = s;
        }
    }
    c
}

/// Gram matrix AᵀA (symmetric; only upper triangle computed, mirrored).
pub fn at_a(a: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let mut c = Matrix::zeros(m, m);
    let c_data = c.as_mut_slice();
    for p in 0..k {
        let row = a.row(p);
        for i in 0..m {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            let crow = &mut c_data[i * m..(i + 1) * m];
            for j in i..m {
                crow[j] += v * row[j];
            }
        }
    }
    // Mirror upper to lower.
    for i in 0..m {
        for j in (i + 1)..m {
            c_data[j * m + i] = c_data[i * m + j];
        }
    }
    c
}

/// Outer Gram matrix AAᵀ.
pub fn a_at(a: &Matrix) -> Matrix {
    let (m, _) = a.shape();
    let mut c = Matrix::zeros(m, m);
    for i in 0..m {
        let ri = a.row(i);
        for j in i..m {
            let rj = a.row(j);
            let mut s = 0.0;
            for p in 0..ri.len() {
                s += ri[p] * rj[p];
            }
            c[(i, j)] = s;
            c[(j, i)] = s;
        }
    }
    c
}

/// y = A · x.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for (p, &xp) in x.iter().enumerate() {
        if xp == 0.0 {
            continue;
        }
        let row = a.row(p);
        for j in 0..y.len() {
            y[j] += xp * row[j];
        }
    }
    y
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Outer product u vᵀ.
pub fn outer(u: &[f64], v: &[f64]) -> Matrix {
    let mut m = Matrix::zeros(u.len(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = m.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] = ui * vj;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_diff(&naive_matmul(&a, &b)) < 1e-10);
        }
    }

    #[test]
    fn single_thread_pin_scopes_and_restores() {
        let outer = num_threads();
        let (inner, nested) = with_single_thread(|| {
            let inner = num_threads();
            // Nested pins stay pinned and restore to pinned.
            let nested = with_single_thread(num_threads);
            (inner, nested)
        });
        assert_eq!(inner, 1);
        assert_eq!(nested, 1);
        assert_eq!(num_threads(), outer, "pin leaked past its scope");
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Pcg64::new(3);
        // Big enough to cross PAR_FLOP_THRESHOLD.
        let a = Matrix::randn(160, 160, &mut rng);
        let b = Matrix::randn(160, 160, &mut rng);
        assert!(matmul(&a, &b).max_diff(&naive_matmul(&a, &b)) < 1e-9);
    }

    #[test]
    fn transposed_variants() {
        let mut rng = Pcg64::new(4);
        let a = Matrix::randn(13, 7, &mut rng);
        let b = Matrix::randn(13, 5, &mut rng);
        assert!(at_b(&a, &b).max_diff(&matmul(&a.t(), &b)) < 1e-12);
        let b2 = Matrix::randn(9, 7, &mut rng);
        assert!(a_bt(&a, &b2).max_diff(&matmul(&a, &b2.t())) < 1e-12);
        assert!(at_a(&a).max_diff(&matmul(&a.t(), &a)) < 1e-12);
        assert!(a_at(&a).max_diff(&matmul(&a, &a.t())) < 1e-12);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg64::new(5);
        let a = Matrix::randn(20, 8, &mut rng);
        let g = at_a(&a);
        assert!(g.is_symmetric(1e-12));
        for i in 0..8 {
            assert!(g[(i, i)] >= 0.0);
        }
    }

    #[test]
    fn matvec_variants() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(matvec(&a, &[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn outer_product() {
        let m = outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 10.0);
    }
}
