//! Dense kernels: matmul (blocked, multithreaded), Gram products, matvec.
//!
//! These are the L3 hot paths of the optimizer family — an S-Shampoo step
//! is dominated by `at_a` / `a_at` (covariance statistics) and three-way
//! products (preconditioner application). The kernels use i-k-j loop
//! order over row-major storage (unit-stride inner loops the compiler can
//! auto-vectorize) and split work across threads by output row blocks.
//!
//! Parallel dispatch runs on the persistent worker pool
//! ([`crate::runtime::pool`]) instead of spawning a `std::thread::scope`
//! per call: the row partition is computed here (matmul keeps the exact
//! chunk boundaries of the old scoped-thread split; the triangle Gram
//! kernels use finer bands the pool load-balances), each task owns a
//! disjoint band of output rows, and every output element is
//! accumulated entirely within one task in the same order as the serial
//! loop — so results are **bitwise identical** for any thread count and
//! any band split (`tests/pool_runtime.rs`).

use super::matrix::Matrix;
use crate::runtime::pool;
use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    /// When set, dense kernels on this thread stay single-threaded. The
    /// block engine's workers pin this so per-block math never nests a
    /// second level of threading (oversubscription).
    static SINGLE_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with this thread's dense kernels pinned to one thread
/// (restores the previous setting on exit; results are identical — the
/// kernels' row partition does not change the arithmetic).
pub fn with_single_thread<R>(f: impl FnOnce() -> R) -> R {
    SINGLE_THREAD.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// Number of worker threads for the dense kernels. Resolution order:
/// [`with_single_thread`] pin, `SKETCHY_THREADS` env var, then available
/// parallelism, capped at 16. The env/parallelism resolution is cached
/// in a `OnceLock` on first use — this runs on every kernel call, so the
/// hot path must not re-read and re-parse the environment (the pin stays
/// a live thread-local check, so test overrides via the pin keep
/// working).
pub fn num_threads() -> usize {
    if SINGLE_THREAD.with(|s| s.get()) {
        return 1;
    }
    static RESOLVED: OnceLock<usize> = OnceLock::new();
    *RESOLVED.get_or_init(|| {
        if let Ok(s) = std::env::var("SKETCHY_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(16)
    })
}

/// Threshold (in multiply-adds) below which kernels stay single-threaded.
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

/// Disjoint-band pointer into an output buffer, so pool tasks can each
/// take `&mut` to their own row band. Safety is the caller's: bands must
/// not overlap, and the buffer must outlive the phase (the pool's `run`
/// barriers before returning).
#[derive(Clone, Copy)]
struct BandPtr(*mut f64);
unsafe impl Send for BandPtr {}
unsafe impl Sync for BandPtr {}

impl BandPtr {
    /// The band `[offset, offset + len)` of the underlying buffer.
    ///
    /// SAFETY: caller guarantees disjointness across concurrent tasks
    /// and that the buffer outlives the phase barrier.
    unsafe fn band(self, offset: usize, len: usize) -> &'static mut [f64] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

/// Partition output rows `[0, m)` into contiguous chunks of
/// `ceil(m / (threads · granularity))` rows and run `f(band, r0, r1)`
/// for each on the persistent pool, where `band` is the disjoint window
/// of `out` covering rows `[r0, r1)` (each `row_width` wide).
/// `granularity = 1` reproduces the exact split the pre-pool
/// scoped-thread code used; the triangle kernels pass a finer
/// granularity so the pool's self-scheduling cursor load-balances their
/// descending per-row cost. Every output element is written by exactly
/// one task regardless of the split, so the parallel result is bitwise
/// identical to running the chunks serially at any granularity.
fn par_row_chunks(
    out: &mut [f64],
    m: usize,
    row_width: usize,
    threads: usize,
    granularity: usize,
    f: impl Fn(&mut [f64], usize, usize) + Sync,
) {
    debug_assert_eq!(out.len(), m * row_width);
    let chunk = m.div_ceil(threads * granularity.max(1)).max(1);
    let n_chunks = m.div_ceil(chunk);
    let base = BandPtr(out.as_mut_ptr());
    pool::global().run(threads, n_chunks, |ci| {
        let r0 = ci * chunk;
        let r1 = (r0 + chunk).min(m);
        let band = unsafe { base.band(r0 * row_width, (r1 - r0) * row_width) };
        f(band, r0, r1);
    });
}

/// Chunks per thread for the triangular Gram kernels: row `i` of the
/// upper triangle costs `m - i`, so equal-row bands would leave the
/// first band with ~2x the mean work; finer bands + self-scheduling
/// even it out.
const TRIANGLE_GRANULARITY: usize = 4;

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch {:?} x {:?}", a.shape(), b.shape());
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = A · B written into an existing buffer (C is overwritten).
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(b.rows(), k);
    assert_eq!(c.shape(), (m, n));
    let flops = m * n * k;
    let threads = num_threads();
    if flops < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        matmul_rows_offset(a, b, c.as_mut_slice(), 0, m);
        return;
    }
    par_row_chunks(c.as_mut_slice(), m, n, threads, 1, |band, r0, r1| {
        matmul_rows_offset(a, b, band, r0, r1);
    });
}

/// Compute rows [r0, r1) of A·B into `out`, where out[0..] corresponds
/// to row r0 of C; `out` is overwritten. i-k-j order: for each output
/// row, the first contributing row of B is written directly and the rest
/// accumulate — no separate zero-fill pass over C (rows of A with no
/// nonzero entry still zero their output row). Unit stride everywhere.
fn matmul_rows_offset(a: &Matrix, b: &Matrix, out: &mut [f64], r0: usize, r1: usize) {
    let k = a.cols();
    let n = b.cols();
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
        let mut wrote = false;
        for p in 0..k {
            let aip = arow[p];
            if aip == 0.0 {
                continue;
            }
            let brow = b.row(p);
            if wrote {
                // Unit-stride AXPY the compiler vectorizes.
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            } else {
                // First contribution replaces the old full zero-fill
                // pass over C. The explicit `0.0 +` keeps the exact
                // arithmetic of that path (fill then accumulate) so the
                // result stays bitwise identical even when the first
                // product is -0.0 (0.0 + -0.0 == +0.0, while a direct
                // store would keep the sign bit).
                for j in 0..n {
                    crow[j] = 0.0 + aip * brow[j];
                }
                wrote = true;
            }
        }
        if !wrote {
            crow.fill(0.0);
        }
    }
}

/// C = Aᵀ · B without materializing Aᵀ.
pub fn at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "at_b shape mismatch");
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    let threads = num_threads();
    if k * m * n < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        at_b_rows(a, b, c.as_mut_slice(), 0, m);
    } else {
        par_row_chunks(c.as_mut_slice(), m, n, threads, 1, |band, r0, r1| {
            at_b_rows(a, b, band, r0, r1);
        });
    }
    c
}

/// Rows [i0, i1) of AᵀB into `out` (out[0..] is row i0).
/// (AᵀB)[i][j] = Σ_p A[p][i] B[p][j]; loop p outermost, rows of A and B
/// both unit stride; accumulation over p is ascending for every element,
/// independent of the band split.
fn at_b_rows(a: &Matrix, b: &Matrix, out: &mut [f64], i0: usize, i1: usize) {
    let k = a.rows();
    let n = b.cols();
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in i0..i1 {
            let api = arow[i];
            if api == 0.0 {
                continue;
            }
            let crow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
            for j in 0..n {
                crow[j] += api * brow[j];
            }
        }
    }
}

/// C = A · Bᵀ without materializing Bᵀ.
pub fn a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "a_bt shape mismatch");
    let (m, k) = a.shape();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    let threads = num_threads();
    if m * n * k < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        a_bt_rows(a, b, c.as_mut_slice(), 0, m);
    } else {
        par_row_chunks(c.as_mut_slice(), m, n, threads, 1, |band, r0, r1| {
            a_bt_rows(a, b, band, r0, r1);
        });
    }
    c
}

/// Rows [i0, i1) of A·Bᵀ into `out` (out[0..] is row i0).
fn a_bt_rows(a: &Matrix, b: &Matrix, out: &mut [f64], i0: usize, i1: usize) {
    let k = a.cols();
    let n = b.rows();
    for i in i0..i1 {
        let arow = a.row(i);
        let crow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
        #[allow(clippy::needless_range_loop)]
        for j in 0..n {
            let brow = b.row(j);
            let mut s = 0.0;
            for p in 0..k {
                s += arow[p] * brow[p];
            }
            crow[j] = s;
        }
    }
}

/// Gram matrix AᵀA — the S-Shampoo covariance-statistics kernel. Only
/// the upper triangle is computed (half the flops of a full product),
/// mirrored afterwards; the triangle rows are band-partitioned across
/// the pool.
pub fn at_a(a: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let mut c = Matrix::zeros(m, m);
    let threads = num_threads();
    // Upper triangle only: ~k·m²/2 multiply-adds.
    if k * m * m / 2 < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        at_a_rows(a, c.as_mut_slice(), 0, m);
    } else {
        par_row_chunks(c.as_mut_slice(), m, m, threads, TRIANGLE_GRANULARITY, |band, i0, i1| {
            at_a_rows(a, band, i0, i1);
        });
    }
    mirror_upper(&mut c);
    c
}

/// Upper-triangle rows [i0, i1) of AᵀA into `out` (out[0..] is row i0).
fn at_a_rows(a: &Matrix, out: &mut [f64], i0: usize, i1: usize) {
    let (k, m) = a.shape();
    for p in 0..k {
        let row = a.row(p);
        for i in i0..i1 {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            let crow = &mut out[(i - i0) * m..(i - i0 + 1) * m];
            for j in i..m {
                crow[j] += v * row[j];
            }
        }
    }
}

/// Outer Gram matrix AAᵀ. Upper triangle only (half the flops),
/// band-partitioned across the pool, mirrored afterwards.
pub fn a_at(a: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let mut c = Matrix::zeros(m, m);
    let threads = num_threads();
    if m * m * k / 2 < PAR_FLOP_THRESHOLD || threads == 1 || m < 2 {
        a_at_rows(a, c.as_mut_slice(), 0, m);
    } else {
        par_row_chunks(c.as_mut_slice(), m, m, threads, TRIANGLE_GRANULARITY, |band, i0, i1| {
            a_at_rows(a, band, i0, i1);
        });
    }
    mirror_upper(&mut c);
    c
}

/// Upper-triangle rows [i0, i1) of AAᵀ into `out` (out[0..] is row i0).
fn a_at_rows(a: &Matrix, out: &mut [f64], i0: usize, i1: usize) {
    let m = a.rows();
    for i in i0..i1 {
        let ri = a.row(i);
        let crow = &mut out[(i - i0) * m..(i - i0 + 1) * m];
        for j in i..m {
            crow[j] = dot(ri, a.row(j));
        }
    }
}

/// Copy the strict upper triangle onto the lower (symmetric output).
fn mirror_upper(c: &mut Matrix) {
    let m = c.rows();
    let data = c.as_mut_slice();
    for i in 0..m {
        for j in (i + 1)..m {
            data[j * m + i] = data[i * m + j];
        }
    }
}

/// y = A · x.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for (p, &xp) in x.iter().enumerate() {
        if xp == 0.0 {
            continue;
        }
        let row = a.row(p);
        for j in 0..y.len() {
            y[j] += xp * row[j];
        }
    }
    y
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Outer product u vᵀ.
pub fn outer(u: &[f64], v: &[f64]) -> Matrix {
    let mut m = Matrix::zeros(u.len(), v.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = m.row_mut(i);
        for (j, &vj) in v.iter().enumerate() {
            row[j] = ui * vj;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for p in 0..a.cols() {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    /// The pre-optimization matmul inner loop: zero-fill C, then
    /// accumulate every k-iteration — the reference the write-first
    /// variant must match bitwise.
    fn zero_fill_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        let out = c.as_mut_slice();
        for i in 0..m {
            let arow = a.row(i);
            let crow = &mut out[i * n..(i + 1) * n];
            crow.fill(0.0);
            for p in 0..k {
                let aip = arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            }
        }
        c
    }

    fn assert_bitwise_eq(a: &Matrix, b: &Matrix, what: &str) {
        assert_eq!(a.shape(), b.shape(), "{what}: shape");
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 64, 64)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_diff(&naive_matmul(&a, &b)) < 1e-10);
        }
    }

    #[test]
    fn write_first_matmul_matches_zero_fill_bitwise() {
        let mut rng = Pcg64::new(7);
        // Dense case plus sparse rows (whole zero rows exercise the
        // no-contribution path the old zero-fill handled implicitly).
        for &(m, k, n) in &[(9, 6, 11), (32, 17, 8)] {
            let mut a = Matrix::randn(m, k, &mut rng);
            for j in 0..k {
                a[(1, j)] = 0.0; // a fully-zero row of A
                if j % 3 == 0 {
                    a[(0, j)] = 0.0; // scattered zeros
                }
            }
            let b = Matrix::randn(k, n, &mut rng);
            assert_bitwise_eq(&matmul(&a, &b), &zero_fill_matmul(&a, &b), "write-first");
            // Dirty output buffers are fully overwritten.
            let mut c = Matrix::randn(m, n, &mut rng);
            matmul_into(&a, &b, &mut c);
            assert_bitwise_eq(&c, &zero_fill_matmul(&a, &b), "dirty-buffer overwrite");
        }
        // Signed-zero edge: when the only contribution is -0.0 the old
        // fill-then-accumulate produced +0.0 (0.0 + -0.0); the
        // write-first path must reproduce that bit pattern, not store
        // the raw -0.0 product.
        let a = Matrix::from_rows(&[vec![-1.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 3.0]]);
        let c = matmul(&a, &b);
        assert_bitwise_eq(&c, &zero_fill_matmul(&a, &b), "signed-zero first contribution");
        assert_eq!(c[(0, 0)].to_bits(), 0f64.to_bits(), "must be +0.0, not -0.0");
        assert_eq!(c[(0, 1)], -3.0);
    }

    #[test]
    fn single_thread_pin_scopes_and_restores() {
        let outer = num_threads();
        let (inner, nested) = with_single_thread(|| {
            let inner = num_threads();
            // Nested pins stay pinned and restore to pinned.
            let nested = with_single_thread(num_threads);
            (inner, nested)
        });
        assert_eq!(inner, 1);
        assert_eq!(nested, 1);
        assert_eq!(num_threads(), outer, "pin leaked past its scope");
        // The cached resolution is stable across calls.
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Pcg64::new(3);
        // Big enough to cross PAR_FLOP_THRESHOLD.
        let a = Matrix::randn(160, 160, &mut rng);
        let b = Matrix::randn(160, 160, &mut rng);
        assert!(matmul(&a, &b).max_diff(&naive_matmul(&a, &b)) < 1e-9);
        // Pooled dispatch is bitwise identical to the pinned-serial path.
        let pooled = matmul(&a, &b);
        let serial = with_single_thread(|| matmul(&a, &b));
        assert_bitwise_eq(&pooled, &serial, "pooled matmul");
    }

    #[test]
    fn transposed_variants() {
        let mut rng = Pcg64::new(4);
        let a = Matrix::randn(13, 7, &mut rng);
        let b = Matrix::randn(13, 5, &mut rng);
        assert!(at_b(&a, &b).max_diff(&matmul(&a.t(), &b)) < 1e-12);
        let b2 = Matrix::randn(9, 7, &mut rng);
        assert!(a_bt(&a, &b2).max_diff(&matmul(&a, &b2.t())) < 1e-12);
        assert!(at_a(&a).max_diff(&matmul(&a.t(), &a)) < 1e-12);
        assert!(a_at(&a).max_diff(&matmul(&a, &a.t())) < 1e-12);
    }

    #[test]
    fn gram_kernels_match_oracle_above_parallel_threshold() {
        // Sizes that cross PAR_FLOP_THRESHOLD so the pooled triangle
        // path runs; validated against the full-product oracle.
        let mut rng = Pcg64::new(6);
        let a = Matrix::randn(400, 96, &mut rng);
        let g = at_a(&a);
        assert!(g.max_diff(&matmul(&a.t(), &a)) < 1e-12 * 400.0);
        assert!(g.is_symmetric(0.0));
        let b = Matrix::randn(96, 400, &mut rng);
        let h = a_at(&b);
        assert!(h.max_diff(&matmul(&b, &b.t())) < 1e-12 * 400.0);
        assert!(h.is_symmetric(0.0));
        // Parallel ≡ pinned-serial, bitwise.
        assert_bitwise_eq(&g, &with_single_thread(|| at_a(&a)), "pooled at_a");
        assert_bitwise_eq(&h, &with_single_thread(|| a_at(&b)), "pooled a_at");
        let c = Matrix::randn(400, 64, &mut rng);
        assert_bitwise_eq(&at_b(&a, &c), &with_single_thread(|| at_b(&a, &c)), "pooled at_b");
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg64::new(5);
        let a = Matrix::randn(20, 8, &mut rng);
        let g = at_a(&a);
        assert!(g.is_symmetric(1e-12));
        for i in 0..8 {
            assert!(g[(i, i)] >= 0.0);
        }
    }

    #[test]
    fn matvec_variants() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(matvec(&a, &[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn outer_product() {
        let m = outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 10.0);
    }
}
