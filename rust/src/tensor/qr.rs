//! Reduced QR factorization (modified Gram–Schmidt with reorthogonalization).
//!
//! Used by the exact low-rank AdaGrad recovery discussed in §3.3 of the
//! paper ("tracking the column space of observed gradients ... with a
//! reduced QR decomposition, rank-1-updated every step") and by tests
//! needing random orthonormal frames.

use super::matrix::Matrix;
use super::ops::{dot, norm2};

/// Reduced QR: `a (m×n, m ≥ n)` = `q (m×n, orthonormal cols)` · `r (n×n,
/// upper triangular)`. Columns of `a` that are (numerically) dependent
/// yield zero columns in `q` and zero rows in `r`.
pub struct Qr {
    pub q: Matrix,
    pub r: Matrix,
}

/// Modified Gram–Schmidt with one reorthogonalization pass.
pub fn qr(a: &Matrix) -> Qr {
    let (m, n) = a.shape();
    let mut q = a.clone();
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        let mut v = q.col(j);
        // Two MGS passes for numerical orthogonality.
        for _pass in 0..2 {
            for i in 0..j {
                let qi = q.col(i);
                let proj = dot(&qi, &v);
                r[(i, j)] += proj;
                for k in 0..m {
                    v[k] -= proj * qi[k];
                }
            }
        }
        let nv = norm2(&v);
        r[(j, j)] = nv;
        if nv > 1e-12 {
            for x in &mut v {
                *x /= nv;
            }
        } else {
            // Dependent column: zero it out.
            r[(j, j)] = 0.0;
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        q.set_col(j, &v);
    }
    Qr { q, r }
}

/// Random m×n matrix with orthonormal columns (QR of a Gaussian).
pub fn random_orthonormal(m: usize, n: usize, rng: &mut crate::util::rng::Pcg64) -> Matrix {
    assert!(m >= n);
    let g = Matrix::randn(m, n, rng);
    qr(&g).q
}

/// Rank-1 update of an orthonormal basis: extend `q` (m×k) with the
/// component of `v` orthogonal to span(q), if significant. Returns true if
/// a column was appended. This is the O(dk) column-space tracker from
/// §3.3 of the paper.
pub fn extend_basis(q: &mut Vec<Vec<f64>>, v: &[f64], tol: f64) -> bool {
    let mut w = v.to_vec();
    for _pass in 0..2 {
        for qi in q.iter() {
            let proj = dot(qi, &w);
            for k in 0..w.len() {
                w[k] -= proj * qi[k];
            }
        }
    }
    let nv = norm2(&w);
    if nv > tol * (1.0 + norm2(v)) {
        for x in &mut w {
            *x /= nv;
        }
        q.push(w);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{at_a, matmul};
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::new(20);
        for &(m, n) in &[(5, 3), (10, 10), (40, 7)] {
            let a = Matrix::randn(m, n, &mut rng);
            let f = qr(&a);
            assert!(matmul(&f.q, &f.r).max_diff(&a) < 1e-10);
            assert!(at_a(&f.q).max_diff(&Matrix::eye(n)) < 1e-10);
            // Upper-triangular r.
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(f.r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn qr_rank_deficient() {
        let mut rng = Pcg64::new(21);
        let b = Matrix::randn(8, 2, &mut rng);
        let c = Matrix::randn(2, 4, &mut rng);
        let a = matmul(&b, &c); // rank 2, 4 columns
        let f = qr(&a);
        assert!(matmul(&f.q, &f.r).max_diff(&a) < 1e-9);
        let nonzero_cols = (0..4).filter(|&j| f.r[(j, j)].abs() > 1e-9).count();
        assert_eq!(nonzero_cols, 2);
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Pcg64::new(22);
        let q = random_orthonormal(16, 5, &mut rng);
        assert!(at_a(&q).max_diff(&Matrix::eye(5)) < 1e-10);
    }

    #[test]
    fn extend_basis_tracks_column_space() {
        let mut rng = Pcg64::new(23);
        let mut basis: Vec<Vec<f64>> = vec![];
        let d = 12;
        let dirs = random_orthonormal(d, 3, &mut rng);
        // Stream vectors from a 3-dim subspace; basis must stop at 3.
        for t in 0..50 {
            let mut v = vec![0.0; d];
            for j in 0..3 {
                let c = rng.gaussian();
                for i in 0..d {
                    v[i] += c * dirs[(i, j)];
                }
            }
            extend_basis(&mut basis, &v, 1e-8);
            if t >= 3 {
                assert!(basis.len() <= 3);
            }
        }
        assert_eq!(basis.len(), 3);
    }
}
