//! Matrix root computations for preconditioners.
//!
//! Shampoo needs `L^{-1/4}` and `R^{-1/4}`; AdaGrad variants need
//! `G^{-1/2}`. We compute roots spectrally through [`eigh`] (the paper's
//! `eigh=true` configuration, App. E: "we believe it has better numerical
//! stability" than coupled Newton iterations) with an ε-style ridge on the
//! spectrum, plus a coupled-Newton implementation kept for an ablation
//! bench of that very design choice.

use super::eigh::eigh;
use super::matrix::Matrix;
use super::ops::matmul;

/// `a^{-1/p}` for symmetric PSD `a` via eigendecomposition. Eigenvalues
/// are floored at `ridge` before the root (the Shampoo epsilon).
pub fn inv_pth_root(a: &Matrix, p: f64, ridge: f64) -> Matrix {
    let e = eigh(a);
    e.apply_spectral(|w| (w.max(0.0) + ridge).powf(-1.0 / p))
}

/// `a^{1/p}` for symmetric PSD `a`.
pub fn pth_root(a: &Matrix, p: f64) -> Matrix {
    let e = eigh(a);
    e.apply_spectral(|w| w.max(0.0).powf(1.0 / p))
}

/// Moore–Penrose pseudo-inverse square root `(a^{1/2})^+` with tolerance-
/// based null-space handling (Alg. 2 uses the pseudoinverse when the
/// preconditioner is singular).
pub fn pinv_sqrt(a: &Matrix, tol: f64) -> Matrix {
    let e = eigh(a);
    let wmax = e.w.first().copied().unwrap_or(0.0).max(0.0);
    let cut = tol * (1.0 + wmax);
    e.apply_spectral(|w| if w > cut { 1.0 / w.sqrt() } else { 0.0 })
}

/// Coupled-Newton iteration for `a^{-1/p}` (integer p ≥ 1), the
/// alternative Shampoo uses when eigh is disabled. Kept for the ablation
/// bench comparing root computation strategies (DESIGN.md §8).
///
/// Iterates `M_{k+1} = ((1+1/p) I - X_k/p) M_k`, `X_{k+1} = ...` in the
/// standard coupled form with a spectral-norm prescaling.
pub fn inv_pth_root_newton(a: &Matrix, p: u32, ridge: f64, iters: usize) -> Matrix {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut a_r = a.clone();
    a_r.add_diag(ridge);
    // Prescale so the spectrum is within (0, 1]: z = 1/||A||_F is a safe
    // (if loose) bound on 1/λmax.
    let z = 1.0 / a_r.fro_norm().max(1e-30);
    let mut x = a_r.scale(z); // X_0 = z·A, spectrum in (0,1]
    let mut m = Matrix::eye(n); // M_0 = I
    let pf = p as f64;
    for _ in 0..iters {
        // T = ((p+1) I - X) / p
        let mut t = x.scale(-1.0 / pf);
        t.add_diag((pf + 1.0) / pf);
        m = matmul(&m, &t);
        // X = T^p · X
        let mut tp = t.clone();
        for _ in 1..p {
            tp = matmul(&tp, &t);
        }
        x = matmul(&tp, &x);
        // Converged when X ≈ I.
        let mut dev: f64 = 0.0;
        for i in 0..n {
            dev = dev.max((x[(i, i)] - 1.0).abs());
        }
        if dev < 1e-12 {
            break;
        }
    }
    // A^{-1/p} = z^{1/p} · M.
    m.scale(z.powf(1.0 / pf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::at_a;
    use crate::util::rng::Pcg64;

    fn random_pd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let g = Matrix::randn(2 * n, n, &mut rng);
        let mut a = at_a(&g);
        a.add_diag(0.1);
        a
    }

    #[test]
    fn inv_sqrt_inverts() {
        let a = random_pd(8, 40);
        let r = inv_pth_root(&a, 2.0, 0.0);
        // r·a·r == I
        let prod = matmul(&matmul(&r, &a), &r);
        assert!(prod.max_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn inv_fourth_root_squares_to_inv_sqrt() {
        let a = random_pd(6, 41);
        let r4 = inv_pth_root(&a, 4.0, 0.0);
        let r2 = inv_pth_root(&a, 2.0, 0.0);
        assert!(matmul(&r4, &r4).max_diff(&r2) < 1e-8);
    }

    #[test]
    fn pth_root_composes() {
        let a = random_pd(5, 42);
        let s = pth_root(&a, 2.0);
        assert!(matmul(&s, &s).max_diff(&a) < 1e-8);
    }

    #[test]
    fn pinv_sqrt_handles_singular() {
        let mut rng = Pcg64::new(43);
        let g = Matrix::randn(3, 7, &mut rng);
        let a = at_a(&g); // rank 3 in dim 7
        let r = pinv_sqrt(&a, 1e-10);
        // r² should be a^+ : a · r² · a == a.
        let r2 = matmul(&r, &r);
        let back = matmul(&matmul(&a, &r2), &a);
        assert!(back.max_diff(&a) < 1e-6 * (1.0 + a.max_abs()));
    }

    #[test]
    fn newton_matches_eigh_root() {
        for p in [1u32, 2, 4] {
            let a = random_pd(6, 44 + p as u64);
            let newton = inv_pth_root_newton(&a, p, 1e-6, 200);
            let spectral = inv_pth_root(&a, p as f64, 1e-6);
            assert!(
                newton.max_diff(&spectral) < 1e-5 * (1.0 + spectral.max_abs()),
                "p={p}: diff {}",
                newton.max_diff(&spectral)
            );
        }
    }

    #[test]
    fn ridge_bounds_condition() {
        // Singular matrix + ridge should still give finite root.
        let a = Matrix::zeros(4, 4);
        let r = inv_pth_root(&a, 2.0, 1e-4);
        for i in 0..4 {
            assert!((r[(i, i)] - 1e2).abs() < 1e-6); // (1e-4)^{-1/2}
        }
    }
}
