//! Thin SVD via the Gram-matrix eigendecomposition.
//!
//! The FD update (paper §6) works on tall-thin factors `A ∈ R^{d×ℓ}` with
//! ℓ ≪ d, where the right singular structure is all we need: eigh(AᵀA)
//! gives V and Σ², and U = A V Σ⁻¹ for the non-null part. This squares the
//! condition number, which is acceptable here because FD consumes only the
//! *leading* singular values (and deflates by σ_ℓ²) — the tail inaccuracy
//! FD is already robust to. Tests pin accuracy against reconstruction.

use super::eigh::eigh;
use super::matrix::Matrix;
use super::ops::{at_a, matmul};

/// Thin SVD result: `a = u · diag(s) · vᵀ` with s descending,
/// `u: m×k`, `v: n×k`, `k = min(m, n)` (columns beyond the numerical rank
/// are zero in `u`).
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix,
}

/// Thin SVD of `a` (any shape) via eigh of the smaller Gram matrix.
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m >= n {
        // AᵀA = V Σ² Vᵀ.
        let g = at_a(a);
        let e = eigh(&g);
        let k = n;
        let mut s = Vec::with_capacity(k);
        for &w in &e.w {
            s.push(w.max(0.0).sqrt());
        }
        // U = A V Σ⁻¹ (zero column where σ ~ 0).
        let av = matmul(a, &e.q);
        let mut u = Matrix::zeros(m, k);
        for j in 0..k {
            if s[j] > 1e-12 {
                for i in 0..m {
                    u[(i, j)] = av[(i, j)] / s[j];
                }
            }
        }
        Svd { u, s, v: e.q }
    } else {
        // Factor the transpose and swap.
        let f = svd(&a.t());
        Svd { u: f.v, s: f.s, v: f.u }
    }
}

/// Best rank-k approximation of `a` in Frobenius norm (Eckart–Young).
pub fn low_rank_approx(a: &Matrix, k: usize) -> Matrix {
    let f = svd(a);
    let k = k.min(f.s.len());
    let (m, n) = a.shape();
    let mut out = Matrix::zeros(m, n);
    for r in 0..k {
        let sr = f.s[r];
        if sr <= 0.0 {
            break;
        }
        for i in 0..m {
            let uis = f.u[(i, r)] * sr;
            let row = out.row_mut(i);
            for j in 0..n {
                row[j] += uis * f.v[(j, r)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn check_svd(a: &Matrix, tol: f64) {
        let f = svd(a);
        let k = f.s.len();
        assert_eq!(k, a.rows().min(a.cols()));
        // Descending, nonnegative.
        for i in 0..k {
            assert!(f.s[i] >= -1e-12);
            if i > 0 {
                assert!(f.s[i - 1] >= f.s[i] - 1e-10);
            }
        }
        // Reconstruction.
        let mut us = f.u.clone();
        for j in 0..k {
            for i in 0..a.rows() {
                us[(i, j)] *= f.s[j];
            }
        }
        let recon = super::super::ops::a_bt(&us, &f.v);
        assert!(
            recon.max_diff(a) < tol * (1.0 + a.max_abs()),
            "svd recon err {}",
            recon.max_diff(a)
        );
    }

    #[test]
    fn svd_tall_square_wide() {
        let mut rng = Pcg64::new(30);
        for &(m, n) in &[(12, 4), (6, 6), (4, 12)] {
            let a = Matrix::randn(m, n, &mut rng);
            check_svd(&a, 1e-7);
        }
    }

    #[test]
    fn svd_rank_deficient() {
        let mut rng = Pcg64::new(31);
        let b = Matrix::randn(10, 2, &mut rng);
        let c = Matrix::randn(2, 7, &mut rng);
        let a = matmul(&b, &c);
        let f = svd(&a);
        for &s in &f.s[2..] {
            assert!(s < 1e-6, "rank-2 matrix had σ tail {:?}", f.s);
        }
        check_svd(&a, 1e-6);
    }

    #[test]
    fn eckart_young() {
        let mut rng = Pcg64::new(32);
        let a = Matrix::randn(9, 9, &mut rng);
        let f = svd(&a);
        for k in [1usize, 3, 6] {
            let ak = low_rank_approx(&a, k);
            let err = a.sub(&ak).fro_norm();
            let expected: f64 = f.s[k..].iter().map(|s| s * s).sum::<f64>().sqrt();
            assert!(
                (err - expected).abs() < 1e-6 * (1.0 + expected),
                "k={k}: err={err} expected={expected}"
            );
        }
    }

    #[test]
    fn singular_values_match_known() {
        // diag(3, 2) embedded in 3x2.
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 2.0], vec![0.0, 0.0]]);
        let f = svd(&a);
        assert!((f.s[0] - 3.0).abs() < 1e-10);
        assert!((f.s[1] - 2.0).abs() < 1e-10);
    }
}
