//! Generic [`GradientWorker`] over a PJRT gradient artifact.
//!
//! The leader converts the (f64) parameters to f32 buffers once per step;
//! each worker thread builds its own literals (xla literals are not Send)
//! from the shared buffers plus its own microbatch inputs, executes the
//! artifact, and parses (loss, grads).

use crate::coordinator::GradientWorker;
use crate::runtime::literal::{lit_f32, lit_i32, lit_scalar, lit_to_matrix};
use crate::runtime::Runtime;
use crate::tensor::Matrix;
use anyhow::Result;

/// A microbatch input buffer (matches the artifact's non-parameter
/// inputs, in manifest order).
#[derive(Clone, Debug)]
pub enum InputBuf {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl InputBuf {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            InputBuf::F32(data, shape) => lit_f32(data, shape),
            InputBuf::I32(data, shape) => lit_i32(data, shape),
        }
    }
}

/// One-step gradient worker: shared parameter buffers + per-worker
/// microbatch inputs.
pub struct ArtifactGradWorker<'a> {
    pub runtime: &'a Runtime,
    pub artifact: &'a str,
    /// Parameter buffers (f32) + shapes, shared by all workers.
    pub param_bufs: &'a [Vec<f32>],
    pub shapes: &'a [(usize, usize)],
    /// Per-worker microbatch inputs: `batches[worker]` lists the
    /// non-parameter inputs in manifest order.
    pub batches: &'a [Vec<InputBuf>],
}

impl GradientWorker for ArtifactGradWorker<'_> {
    fn compute(&self, _step: usize, worker: usize) -> Result<(f64, Vec<Matrix>)> {
        let mut inputs = Vec::with_capacity(self.param_bufs.len() + 2);
        for (buf, &(r, c)) in self.param_bufs.iter().zip(self.shapes) {
            inputs.push(lit_f32(buf, &[r, c])?);
        }
        for b in &self.batches[worker] {
            inputs.push(b.to_literal()?);
        }
        let outs = self.runtime.execute(self.artifact, &inputs)?;
        let loss = lit_scalar(&outs[0])?;
        let mut grads = Vec::with_capacity(self.shapes.len());
        for (i, &(r, c)) in self.shapes.iter().enumerate() {
            grads.push(lit_to_matrix(&outs[1 + i], r, c)?);
        }
        Ok((loss, grads))
    }
}

/// Convert f64 parameter matrices to flat f32 buffers (leader-side, once
/// per step).
pub fn params_to_f32(params: &[Matrix]) -> Vec<Vec<f32>> {
    params
        .iter()
        .map(|p| p.as_slice().iter().map(|&x| x as f32).collect())
        .collect()
}

/// Initialize parameters from manifest input specs: `*_scale` vectors to
/// ones, everything else scaled Gaussian (matches the python init scheme
/// in spirit; exact values differ, which is fine — Rust owns training).
pub fn init_params_from_specs(
    specs: &[crate::runtime::IoSpec],
    n_params: usize,
    seed: u64,
) -> (Vec<String>, Vec<(usize, usize)>, Vec<Matrix>) {
    let mut rng = crate::util::rng::Pcg64::new(seed);
    let mut names = vec![];
    let mut shapes = vec![];
    let mut params = vec![];
    for spec in specs.iter().take(n_params) {
        assert_eq!(spec.shape.len(), 2, "parameter {} is not 2-D", spec.name);
        let (r, c) = (spec.shape[0], spec.shape[1]);
        let m = if spec.name.ends_with("_scale") {
            Matrix::from_fn(r, c, |_, _| 1.0)
        } else {
            let scale = 1.0 / (r as f64).sqrt();
            Matrix::from_fn(r, c, |_, _| scale * rng.gaussian())
        };
        names.push(spec.name.clone());
        shapes.push((r, c));
        params.push(m);
    }
    (names, shapes, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::IoSpec;

    #[test]
    fn init_respects_scale_convention() {
        let specs = vec![
            IoSpec { name: "w".into(), shape: vec![4, 4], dtype: "f32".into() },
            IoSpec { name: "ln_scale".into(), shape: vec![4, 1], dtype: "f32".into() },
            IoSpec { name: "tokens".into(), shape: vec![2, 3], dtype: "i32".into() },
        ];
        let (names, shapes, params) = init_params_from_specs(&specs, 2, 1);
        assert_eq!(names, vec!["w", "ln_scale"]);
        assert_eq!(shapes, vec![(4, 4), (4, 1)]);
        assert!(params[1].as_slice().iter().all(|&v| v == 1.0));
        assert!(params[0].fro_norm() > 0.0);
    }

    #[test]
    fn params_to_f32_narrows() {
        let p = vec![Matrix::from_rows(&[vec![1.5, -2.5]])];
        let bufs = params_to_f32(&p);
        assert_eq!(bufs[0], vec![1.5f32, -2.5]);
    }
}
