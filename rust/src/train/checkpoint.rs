//! Checkpointing: a simple self-describing binary format for parameter
//! lists plus the step counter (serde is not vendored).
//!
//! Layout: magic "SKCH" | u32 version | u64 step | u32 tensor count |
//! per tensor: u32 rows | u32 cols | rows*cols f64 little-endian.

use crate::tensor::Matrix;
use anyhow::{bail, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"SKCH";
const VERSION: u32 = 1;

/// Save parameters + step to `path`.
pub fn save_checkpoint(path: &str, step: usize, params: &[Matrix]) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(step as u64).to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for p in params {
        f.write_all(&(p.rows() as u32).to_le_bytes())?;
        f.write_all(&(p.cols() as u32).to_le_bytes())?;
        for &v in p.as_slice() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a checkpoint; returns (step, params).
pub fn load_checkpoint(path: &str) -> Result<(usize, Vec<Matrix>)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a sketchy checkpoint: bad magic");
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u64buf)?;
    let step = u64::from_le_bytes(u64buf) as usize;
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut params = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        f.read_exact(&mut u32buf)?;
        let cols = u32::from_le_bytes(u32buf) as usize;
        let mut data = vec![0.0f64; rows * cols];
        let mut vbuf = [0u8; 8];
        for v in &mut data {
            f.read_exact(&mut vbuf)?;
            *v = f64::from_le_bytes(vbuf);
        }
        params.push(Matrix::from_vec(rows, cols, data));
    }
    Ok((step, params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(500);
        let params = vec![
            Matrix::randn(3, 4, &mut rng),
            Matrix::randn(1, 1, &mut rng),
            Matrix::zeros(2, 5),
        ];
        let path = std::env::temp_dir().join("sketchy_ckpt_test.bin");
        let path = path.to_str().unwrap();
        save_checkpoint(path, 42, &params).unwrap();
        let (step, loaded) = load_checkpoint(path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.len(), 3);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("sketchy_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(path.to_str().unwrap()).is_err());
        std::fs::remove_file(path).ok();
    }
}
