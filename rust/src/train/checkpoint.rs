//! Checkpointing: a simple self-describing binary format for parameter
//! lists plus the step counter (serde is not vendored).
//!
//! Layout (v2): magic "SKCH" | u32 version | u64 step | u32 tensor
//! count | per tensor: u32 rows | u32 cols | rows*cols f64
//! little-endian | u8 has_state | \[one wire `StateSnapOk` frame\].
//!
//! The optional tail is the **typed optimizer state**: the same
//! [`BlockStateMsg`] records the wire v4 `StateSnap` RPC ships, encoded
//! as one length-prefixed [`crate::coordinator::wire`] frame. FD-sketched
//! blocks therefore cost O(dℓ) in the checkpoint exactly as on the wire
//! — rank-ℓ factors + escaped-mass scalar, never the O(d²) dense
//! covariance. Version-1 files (params only) still load.
//!
//! Durability: [`save_checkpoint`] is **atomic** — it writes to
//! `<path>.tmp`, flushes and fsyncs, then renames over the final path,
//! so a crash mid-write can only ever leave (a) the previous complete
//! checkpoint at `path` plus a stray `.tmp`, never a truncated file
//! that later fails to load. [`load_checkpoint`] trusts nothing: every
//! header field is bounded by the bytes actually remaining in the
//! file, so a corrupt or truncated checkpoint is a clean error, not an
//! allocation bomb (the same class of bug the shard wire reader
//! guards against — the embedded state frame reuses that reader, whose
//! buffers grow only as bytes actually arrive).

use crate::coordinator::wire::{self, BlockStateMsg, StateSnapOkMsg, WireMsg};
use crate::tensor::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"SKCH";
const VERSION: u32 = 2;
/// Params-only layout (pre-typed-state); still accepted by the loader.
const VERSION_V1: u32 = 1;

/// Fixed header size: magic + version + step + tensor count.
const HEADER_BYTES: u64 = 4 + 4 + 8 + 4;

/// Save parameters + step to `path` — atomically: write `<path>.tmp`,
/// flush + fsync, rename. Readers concurrently loading `path` always
/// see a complete checkpoint (old or new, never a torn one).
pub fn save_checkpoint(path: &str, step: usize, params: &[Matrix]) -> Result<()> {
    save_checkpoint_with_state(path, step, params, None)
}

/// [`save_checkpoint`] plus the typed optimizer state: the
/// [`BlockStateMsg`] records (one per engine block, in block order)
/// travel as an embedded wire `StateSnapOk` frame after the parameter
/// tensors, so sketched blocks persist as factors, not dense blocks.
pub fn save_checkpoint_with_state(
    path: &str,
    step: usize,
    params: &[Matrix],
    state: Option<&[BlockStateMsg]>,
) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    // Pid-suffixed staging name: two processes racing the same
    // checkpoint path stage independently, so one saver can never
    // rename the other's half-written bytes into place.
    let tmp = format!("{path}.{}.tmp", std::process::id());
    let write = || -> Result<()> {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create checkpoint staging file {tmp}"))?;
        let mut f = std::io::BufWriter::new(file);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(step as u64).to_le_bytes())?;
        f.write_all(&(params.len() as u32).to_le_bytes())?;
        for p in params {
            f.write_all(&(p.rows() as u32).to_le_bytes())?;
            f.write_all(&(p.cols() as u32).to_le_bytes())?;
            for &v in p.as_slice() {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        match state {
            Some(entries) => {
                f.write_all(&[1u8])?;
                // One wire frame: the codec's encode-side frame cap and
                // the loader's byte-bounded decode both apply unchanged.
                let msg = WireMsg::StateSnapOk(StateSnapOkMsg { entries: entries.to_vec() });
                wire::write_msg(&mut f, &msg).context("write checkpoint optimizer state")?;
            }
            None => f.write_all(&[0u8])?,
        }
        f.flush()?;
        // Push the bytes to disk before the rename publishes them: a
        // rename alone could land while the data is still cache-only,
        // which is exactly the torn state atomicity is meant to rule out.
        f.get_ref().sync_all().context("sync checkpoint staging file")?;
        Ok(())
    };
    if let Err(e) = write() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish checkpoint {tmp} -> {path}"))?;
    // Make the publish itself durable: without a directory fsync the
    // rename may still be journal-only, and a crash after returning Ok
    // could silently revert `path` to the previous checkpoint.
    #[cfg(unix)]
    {
        let parent = std::path::Path::new(path).parent().filter(|p| !p.as_os_str().is_empty());
        let dir = parent.unwrap_or_else(|| std::path::Path::new("."));
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("sync checkpoint directory {}", dir.display()))?;
    }
    Ok(())
}

/// Load a checkpoint; returns (step, params). Header fields are
/// validated against the file's actual size before any allocation. Any
/// embedded optimizer state is parsed (so corruption never passes) but
/// dropped — params-only consumers need no typed-state plumbing.
pub fn load_checkpoint(path: &str) -> Result<(usize, Vec<Matrix>)> {
    let (step, params, _) = load_checkpoint_full(path)?;
    Ok((step, params))
}

/// Load a checkpoint with its typed optimizer state, when present:
/// `(step, params, state)`. `state` is `None` for v1 files and v2 files
/// saved without state; the returned [`BlockStateMsg`] records are
/// structurally validated by the wire decoder here and shape-validated
/// against the engine's own block table at restore time.
pub fn load_checkpoint_full(path: &str) -> Result<(usize, Vec<Matrix>, Option<Vec<BlockStateMsg>>)> {
    let file = std::fs::File::open(path)?;
    let total = file.metadata()?.len();
    ensure!(
        total >= HEADER_BYTES,
        "not a sketchy checkpoint: {total} bytes is shorter than the header"
    );
    let mut f = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a sketchy checkpoint: bad magic");
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION && version != VERSION_V1 {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u64buf)?;
    let step = u64::from_le_bytes(u64buf) as usize;
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    // Bytes left after the fixed header: every tensor costs at least
    // its own 8-byte shape header, so `count` is bounded by the file
    // size — a corrupt count cannot pre-allocate beyond it.
    let mut remaining = total - HEADER_BYTES;
    ensure!(
        (count as u64) <= remaining / 8,
        "checkpoint header claims {count} tensors but only {remaining} bytes follow"
    );
    let mut params = Vec::with_capacity(count.min((remaining / 8) as usize));
    for k in 0..count {
        f.read_exact(&mut u32buf)?;
        let rows = u32::from_le_bytes(u32buf) as usize;
        f.read_exact(&mut u32buf)?;
        let cols = u32::from_le_bytes(u32buf) as usize;
        remaining -= 8;
        ensure!(
            rows > 0 && cols > 0 && rows <= 1 << 20 && cols <= 1 << 20,
            "checkpoint tensor {k}: implausible shape {rows}x{cols}"
        );
        let need = (rows as u64)
            .checked_mul(cols as u64)
            .and_then(|c| c.checked_mul(8))
            .ok_or_else(|| anyhow::anyhow!("checkpoint tensor {k}: shape overflows"))?;
        ensure!(
            need <= remaining,
            "checkpoint tensor {k} claims {rows}x{cols} ({need} bytes) but only \
             {remaining} bytes remain — truncated or corrupt"
        );
        let mut data = vec![0.0f64; (rows * cols).min((remaining / 8) as usize)];
        remaining -= need;
        let mut vbuf = [0u8; 8];
        for v in &mut data {
            f.read_exact(&mut vbuf)?;
            *v = f64::from_le_bytes(vbuf);
        }
        params.push(Matrix::from_vec(rows, cols, data));
    }
    if version == VERSION_V1 {
        ensure!(remaining == 0, "checkpoint carries {remaining} trailing bytes");
        return Ok((step, params, None));
    }
    ensure!(remaining >= 1, "checkpoint v2 is missing the state flag");
    let mut flag = [0u8; 1];
    f.read_exact(&mut flag)?;
    remaining -= 1;
    let state = match flag[0] {
        0 => {
            ensure!(remaining == 0, "checkpoint carries {remaining} trailing bytes");
            None
        }
        1 => {
            // The wire reader bounds its buffers by bytes actually read,
            // so a corrupt frame length cannot allocate past the file.
            let msg =
                wire::read_msg(&mut f).context("read checkpoint optimizer-state frame")?;
            let WireMsg::StateSnapOk(snap) = msg else {
                bail!("checkpoint state section holds an unexpected wire message");
            };
            let mut probe = [0u8; 1];
            ensure!(
                f.read(&mut probe)? == 0,
                "checkpoint carries trailing bytes after the state frame"
            );
            Some(snap.entries)
        }
        n => bail!("checkpoint state flag {n} is neither 0 nor 1"),
    };
    Ok((step, params, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{EngineConfig, Optimizer, PrecondEngine, ShampooConfig, UnitKind};
    use crate::util::rng::Pcg64;

    fn tmp_path(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("{name}_{}", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    fn sample_params(seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        vec![
            Matrix::randn(3, 4, &mut rng),
            Matrix::randn(1, 1, &mut rng),
            Matrix::randn(2, 5, &mut rng),
        ]
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(500);
        let params = vec![
            Matrix::randn(3, 4, &mut rng),
            Matrix::randn(1, 1, &mut rng),
            Matrix::zeros(2, 5),
        ];
        let path = tmp_path("sketchy_ckpt_test.bin");
        save_checkpoint(&path, 42, &params).unwrap();
        let (step, loaded) = load_checkpoint(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(loaded.len(), 3);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        // No staging file left behind.
        let staged = format!("{path}.{}.tmp", std::process::id());
        assert!(!std::path::Path::new(&staged).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp_path("sketchy_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_under_simulated_crashes() {
        // A crash mid-save leaves the staging `.tmp` torn but the
        // published checkpoint intact: simulate by writing the old
        // checkpoint at `path`, dropping truncated new bytes at
        // `<path>.tmp` (where a crashed writer would leave them), and
        // asserting the load still yields the old checkpoint. Then a
        // completed save over the same path replaces it.
        let path = tmp_path("sketchy_ckpt_atomic.bin");
        let old = sample_params(501);
        save_checkpoint(&path, 7, &old).unwrap();
        let full = std::fs::read(&path).unwrap();
        let new = sample_params(502);
        let staged = format!("{path}.{}.tmp", std::process::id());
        for cut in [0usize, 1, 11, full.len() / 2, full.len() - 1] {
            std::fs::write(&staged, &full[..cut]).unwrap();
            let (step, loaded) = load_checkpoint(&path).expect("old checkpoint must survive");
            assert_eq!(step, 7);
            assert_eq!(loaded[0], old[0]);
        }
        save_checkpoint(&path, 8, &new).unwrap();
        let (step, loaded) = load_checkpoint(&path).unwrap();
        assert_eq!(step, 8);
        assert_eq!(loaded[0], new[0]);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&staged).ok();
    }

    #[test]
    fn every_truncation_errors_cleanly() {
        // Truncate a valid checkpoint at every byte boundary: the load
        // must either succeed (only at full length) or error cleanly —
        // no panic, no giant allocation.
        let path = tmp_path("sketchy_ckpt_trunc.bin");
        save_checkpoint(&path, 3, &sample_params(503)).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(
                load_checkpoint(&path).is_err(),
                "prefix of {cut}/{} bytes must not load",
                full.len()
            );
        }
        std::fs::write(&path, &full).unwrap();
        assert!(load_checkpoint(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn adversarial_headers_cannot_allocate_beyond_the_file() {
        let path = tmp_path("sketchy_ckpt_adversarial.bin");
        let header = |count: u32| {
            let mut b = Vec::new();
            b.extend_from_slice(MAGIC);
            b.extend_from_slice(&VERSION.to_le_bytes());
            b.extend_from_slice(&0u64.to_le_bytes());
            b.extend_from_slice(&count.to_le_bytes());
            b
        };
        // A count lie: u32::MAX tensors in a header-only file.
        std::fs::write(&path, header(u32::MAX)).unwrap();
        assert!(load_checkpoint(&path).is_err());
        // A shape lie: one tensor claiming 2^20 x 2^20 f64s.
        let mut b = header(1);
        b.extend_from_slice(&(1u32 << 20).to_le_bytes());
        b.extend_from_slice(&(1u32 << 20).to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint(&path).is_err());
        // Implausible (beyond-bound) dimensions are rejected outright.
        let mut b = header(1);
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&[0u8; 64]);
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint(&path).is_err());
        // Zero-sized shapes are rejected.
        let mut b = header(1);
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&5u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint(&path).is_err());
        // Trailing garbage after a valid body is rejected, not ignored.
        save_checkpoint(&path, 1, &[Matrix::zeros(2, 2)]).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        full.push(0xEE);
        std::fs::write(&path, &full).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// A small sketched engine (rank 3, blocks with mixed exact and
    /// sketched sides) — the typed-state source for the v2 tests.
    fn sketched_engine(shapes: &[(usize, usize)]) -> PrecondEngine {
        let base = ShampooConfig {
            start_preconditioning_step: 2,
            stat_interval: 1,
            precond_interval: 2,
            ..Default::default()
        };
        let ecfg = EngineConfig {
            threads: 1,
            block_size: 5,
            refresh_interval: 2,
            ..EngineConfig::default()
        };
        crate::optim::ExecutorBuilder::local()
            .build(shapes, UnitKind::Sketched { rank: 3 }, base, ecfg)
            .unwrap()
    }

    /// Params + typed state after a few steps of a sketched engine.
    fn sketched_entries() -> (Vec<Matrix>, Vec<BlockStateMsg>) {
        let shapes = [(9usize, 6), (4, 4)];
        let mut eng = sketched_engine(&shapes);
        let mut rng = Pcg64::new(604);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
        for _ in 0..5 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
            eng.try_step(&mut params, &grads).unwrap();
        }
        (params, eng.state_payloads().unwrap().expect("engine has typed state"))
    }

    #[test]
    fn v2_state_roundtrip_resumes_bitwise() {
        let shapes = [(9usize, 6), (4, 4)];
        let mut rng = Pcg64::new(605);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
        let grads: Vec<Vec<Matrix>> = (0..9)
            .map(|_| shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect())
            .collect();
        let mut eng = sketched_engine(&shapes);
        for g in &grads[..5] {
            eng.try_step(&mut params, g).unwrap();
        }
        let entries = eng.state_payloads().unwrap().expect("engine has typed state");
        let path = tmp_path("sketchy_ckpt_v2_state.bin");
        save_checkpoint_with_state(&path, 5, &params, Some(&entries)).unwrap();
        let (step, loaded, state) = load_checkpoint_full(&path).unwrap();
        assert_eq!(step, 5);
        let state = state.expect("v2 checkpoint carries state");
        // The codec roundtrip is bit-lossless: the decoded records equal
        // the saved ones field for field.
        assert_eq!(state, entries);
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        // Resume: a fresh engine restored from the checkpoint continues
        // bitwise-identically to the uninterrupted one.
        let mut resumed = sketched_engine(&shapes);
        let mut resumed_params = loaded;
        resumed.restore_payloads(step, state).unwrap();
        assert_eq!(resumed.steps(), 5);
        for g in &grads[5..] {
            eng.try_step(&mut params, g).unwrap();
            resumed.try_step(&mut resumed_params, g).unwrap();
        }
        for (a, b) in params.iter().zip(&resumed_params) {
            assert_eq!(a, b);
        }
        // Restoring into an engine with a different block table is
        // refused before anything is applied.
        let mut wrong = sketched_engine(&[(4usize, 4)]);
        let (_, _, state2) = load_checkpoint_full(&path).unwrap();
        assert!(wrong.restore_payloads(5, state2.unwrap()).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v1_checkpoints_still_load() {
        // Hand-build a version-1 file (params only, no state flag): the
        // v2 loader must accept it unchanged and report no state.
        let params = sample_params(504);
        let path = tmp_path("sketchy_ckpt_v1_legacy.bin");
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION_V1.to_le_bytes());
        b.extend_from_slice(&9u64.to_le_bytes());
        b.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for p in &params {
            b.extend_from_slice(&(p.rows() as u32).to_le_bytes());
            b.extend_from_slice(&(p.cols() as u32).to_le_bytes());
            for &v in p.as_slice() {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(&path, &b).unwrap();
        let (step, loaded, state) = load_checkpoint_full(&path).unwrap();
        assert_eq!(step, 9);
        assert!(state.is_none());
        for (a, b) in params.iter().zip(&loaded) {
            assert_eq!(a, b);
        }
        // A v1 file with trailing bytes is still rejected.
        b.push(0);
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_state_truncations_error_cleanly() {
        // Truncate a state-bearing checkpoint at every byte boundary:
        // only the full file loads; every prefix — including cuts inside
        // the embedded state frame — errors cleanly.
        let (params, entries) = sketched_entries();
        let path = tmp_path("sketchy_ckpt_v2_trunc.bin");
        save_checkpoint_with_state(&path, 5, &params, Some(&entries)).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(
                load_checkpoint_full(&path).is_err(),
                "prefix of {cut}/{} bytes must not load",
                full.len()
            );
        }
        std::fs::write(&path, &full).unwrap();
        assert!(load_checkpoint_full(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn adversarial_state_sections_are_rejected() {
        let (params, entries) = sketched_entries();
        let path = tmp_path("sketchy_ckpt_v2_adversarial.bin");
        // Baseline: a no-state save ends in the 0 flag byte.
        save_checkpoint(&path, 5, &params).unwrap();
        let base = std::fs::read(&path).unwrap();
        assert_eq!(*base.last().unwrap(), 0);
        // An out-of-range flag is rejected.
        let mut b = base.clone();
        *b.last_mut().unwrap() = 2;
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        // Flag 1 followed by the wrong wire message is rejected.
        let mut b = base.clone();
        *b.last_mut().unwrap() = 1;
        wire::write_msg(&mut b, &WireMsg::Ok).unwrap();
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        // Flag 1 with a valid snapshot frame loads...
        let mut b = base.clone();
        *b.last_mut().unwrap() = 1;
        wire::write_msg(&mut b, &WireMsg::StateSnapOk(StateSnapOkMsg { entries: entries.clone() }))
            .unwrap();
        std::fs::write(&path, &b).unwrap();
        let (_, _, state) = load_checkpoint_full(&path).unwrap();
        assert_eq!(state.unwrap(), entries);
        // ...but trailing bytes after the frame are rejected.
        b.push(0xEE);
        std::fs::write(&path, &b).unwrap();
        assert!(load_checkpoint_full(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
