//! Durable write-ahead step journal: the driver's crash-recovery log.
//!
//! PR 7's elastic fleet made *worker* failure recoverable by keeping a
//! sync-point state snapshot plus a bounded per-step journal in driver
//! memory. This module persists exactly that object to disk so the
//! *driver* itself can be `kill -9`'d and relaunched with
//! `--resume-journal PATH`, restoring the last synced optimizer state
//! and replaying at most `failover_budget` journaled steps — bitwise
//! identical to the uninterrupted run.
//!
//! Layout: magic "SKJL" | u32 version | **sync section** | zero or
//! more **step records**.
//!
//! - Sync section (rewritten atomically at every sync point, exactly
//!   the checkpoint module's tmp + fsync + rename + directory-fsync
//!   discipline): u64 sync_t | u32 param count | per tensor u32 rows |
//!   u32 cols | rows*cols f64 LE | u8 has_snaps | \[one wire
//!   `StateSnapOk` frame\] | u32 addr count | per addr u32 len | UTF-8
//!   bytes. The snapshot frame carries the **typed** block factors
//!   ([`BlockStateMsg`]): FD-sketched blocks journal as their rank-ℓ
//!   basis + eigenvalues + escaped mass — O(dℓ), never the O(d²) dense
//!   covariance. The addresses are the worker listen addresses at the
//!   sync point, so a relaunched driver can try to re-adopt the
//!   surviving fleet before spawning a fresh one.
//! - Step record (appended + fsynced *before* the step is applied —
//!   write-ahead): u8 tag | u64 t | f64 lr | u32 grad count | per grad
//!   u32 rows | u32 cols | rows*cols f64 LE. Steps are strictly
//!   consecutive from `sync_t + 1`.
//!
//! Recovery tolerates a **torn tail**: the sync section must parse
//! completely (it was published atomically, so anything else is real
//! corruption and errors loudly), but a step region cut mid-record —
//! the expected state after `kill -9` raced an append — recovers every
//! complete record and drops the rest, falling back to the previous
//! sync point plus the surviving replay prefix. Every length field is
//! bounded by the bytes actually remaining in the file before any
//! allocation, mirroring the checkpoint loader's alloc-bomb guards.

use crate::coordinator::wire::{self, BlockStateMsg, StateSnapOkMsg, WireMsg};
use crate::tensor::Matrix;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"SKJL";
const VERSION: u32 = 1;

/// Fixed prefix: magic + version + sync_t + param count.
const HEADER_BYTES: u64 = 4 + 4 + 8 + 4;

/// Step record tag byte.
const REC_STEP: u8 = 2;

/// One journaled step, replayed through the public `Optimizer` surface
/// (`set_lr` + `try_step`) on resume — the engine recomputes every
/// schedule decision (clip scale, stat cadence, refresh due-ness)
/// purely from `t`, so `(t, lr, grads)` is the whole step.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayStep {
    pub t: u64,
    pub lr: f64,
    pub grads: Vec<Matrix>,
}

/// Everything a relaunched driver recovers from a journal.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalContents {
    /// Step count at the journaled sync point (0 = run start).
    pub sync_t: u64,
    /// Full parameter tensors at the sync point.
    pub params: Vec<Matrix>,
    /// Typed optimizer state at the sync point; `None` only at
    /// `sync_t == 0` (a fresh engine needs no restore).
    pub snaps: Option<Vec<BlockStateMsg>>,
    /// Per-seat worker listen addresses at the sync point (empty
    /// string = seat not re-adoptable; spawn fresh).
    pub addrs: Vec<String>,
    /// Surviving journaled steps, strictly consecutive from
    /// `sync_t + 1`.
    pub steps: Vec<ReplayStep>,
    /// Whether a torn/corrupt tail was dropped during recovery.
    pub torn: bool,
}

fn put_tensor(buf: &mut Vec<u8>, m: &Matrix) {
    buf.extend_from_slice(&(m.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(m.cols() as u32).to_le_bytes());
    for &v in m.as_slice() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append-only writer over a published journal file. Constructed by
/// [`JournalWriter::create`], which (re)writes the sync section
/// atomically; [`JournalWriter::append_step`] then appends one fsynced
/// record per step, *before* the step is applied.
#[derive(Debug)]
pub struct JournalWriter {
    path: String,
    file: std::fs::File,
}

impl JournalWriter {
    /// Atomically publish a journal holding only the sync section
    /// (previous step records, now covered by the new snapshot, are
    /// discarded), then reopen it for appends.
    pub fn create(
        path: &str,
        sync_t: u64,
        params: &[Matrix],
        snaps: Option<&[BlockStateMsg]>,
        addrs: &[String],
    ) -> Result<JournalWriter> {
        ensure!(
            sync_t == 0 || snaps.is_some(),
            "journal sync at step {sync_t} needs an optimizer snapshot"
        );
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = format!("{path}.{}.tmp", std::process::id());
        let write = || -> Result<()> {
            let file = std::fs::File::create(&tmp)
                .with_context(|| format!("create journal staging file {tmp}"))?;
            let mut f = std::io::BufWriter::new(file);
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&sync_t.to_le_bytes())?;
            f.write_all(&(params.len() as u32).to_le_bytes())?;
            let mut buf = Vec::new();
            for p in params {
                buf.clear();
                put_tensor(&mut buf, p);
                f.write_all(&buf)?;
            }
            match snaps {
                Some(entries) => {
                    f.write_all(&[1u8])?;
                    let msg = WireMsg::StateSnapOk(StateSnapOkMsg { entries: entries.to_vec() });
                    wire::write_msg(&mut f, &msg).context("write journal optimizer snapshot")?;
                }
                None => f.write_all(&[0u8])?,
            }
            f.write_all(&(addrs.len() as u32).to_le_bytes())?;
            for a in addrs {
                f.write_all(&(a.len() as u32).to_le_bytes())?;
                f.write_all(a.as_bytes())?;
            }
            f.flush()?;
            f.get_ref().sync_all().context("sync journal staging file")?;
            Ok(())
        };
        if let Err(e) = write() {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        std::fs::rename(&tmp, path).with_context(|| format!("publish journal {tmp} -> {path}"))?;
        #[cfg(unix)]
        {
            let parent =
                std::path::Path::new(path).parent().filter(|p| !p.as_os_str().is_empty());
            let dir = parent.unwrap_or_else(|| std::path::Path::new("."));
            std::fs::File::open(dir)
                .and_then(|d| d.sync_all())
                .with_context(|| format!("sync journal directory {}", dir.display()))?;
        }
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("reopen journal {path} for appends"))?;
        Ok(JournalWriter { path: path.to_string(), file })
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Append one step record and fsync it. Called before the step is
    /// sent to the fleet — the journal is write-ahead, so a crash at
    /// any later point can only lose work the journal already covers.
    pub fn append_step(&mut self, t: u64, lr: f64, grads: &[Matrix]) -> Result<()> {
        let mut buf = Vec::new();
        buf.push(REC_STEP);
        buf.extend_from_slice(&t.to_le_bytes());
        buf.extend_from_slice(&lr.to_le_bytes());
        buf.extend_from_slice(&(grads.len() as u32).to_le_bytes());
        for g in grads {
            put_tensor(&mut buf, g);
        }
        self.file.write_all(&buf).context("append journal step record")?;
        self.file.sync_all().context("fsync journal step record")?;
        Ok(())
    }
}

/// Read one shape-prefixed tensor, charging `remaining` before any
/// allocation (the checkpoint loader's alloc-bomb discipline).
fn read_tensor<R: Read>(f: &mut R, remaining: &mut u64, what: &str) -> Result<Matrix> {
    let mut u32buf = [0u8; 4];
    ensure!(*remaining >= 8, "{what}: missing tensor shape header");
    f.read_exact(&mut u32buf)?;
    let rows = u32::from_le_bytes(u32buf) as usize;
    f.read_exact(&mut u32buf)?;
    let cols = u32::from_le_bytes(u32buf) as usize;
    *remaining -= 8;
    ensure!(
        rows > 0 && cols > 0 && rows <= 1 << 20 && cols <= 1 << 20,
        "{what}: implausible shape {rows}x{cols}"
    );
    let need = (rows as u64)
        .checked_mul(cols as u64)
        .and_then(|c| c.checked_mul(8))
        .ok_or_else(|| anyhow::anyhow!("{what}: shape overflows"))?;
    ensure!(
        need <= *remaining,
        "{what} claims {rows}x{cols} ({need} bytes) but only {remaining} bytes remain"
    );
    let mut data = vec![0.0f64; (rows * cols).min((*remaining / 8) as usize)];
    *remaining -= need;
    let mut vbuf = [0u8; 8];
    for v in &mut data {
        f.read_exact(&mut vbuf)?;
        *v = f64::from_le_bytes(vbuf);
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Load a journal for resume. The sync section is validated strictly
/// (it was published atomically; anything short of a complete parse is
/// corruption). The step region recovers every complete, consecutive
/// record and drops a torn tail, reporting it via
/// [`JournalContents::torn`].
pub fn load_journal(path: &str) -> Result<JournalContents> {
    let file = std::fs::File::open(path).with_context(|| format!("open journal {path}"))?;
    let total = file.metadata()?.len();
    ensure!(total >= HEADER_BYTES, "not a sketchy journal: {total} bytes is shorter than the header");
    let mut f = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a sketchy journal: bad magic");
    }
    let mut u32buf = [0u8; 4];
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    if version != VERSION {
        bail!("unsupported journal version {version}");
    }
    f.read_exact(&mut u64buf)?;
    let sync_t = u64::from_le_bytes(u64buf);
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut remaining = total - HEADER_BYTES;
    ensure!(
        (count as u64) <= remaining / 8,
        "journal header claims {count} tensors but only {remaining} bytes follow"
    );
    let mut params = Vec::with_capacity(count.min((remaining / 8) as usize));
    for k in 0..count {
        params.push(read_tensor(&mut f, &mut remaining, &format!("journal tensor {k}"))?);
    }
    ensure!(remaining >= 1, "journal is missing the snapshot flag");
    let mut flag = [0u8; 1];
    f.read_exact(&mut flag)?;
    remaining -= 1;
    let snaps = match flag[0] {
        0 => None,
        1 => {
            // Read the embedded frame with exact byte accounting (the
            // addr list and step records follow, so the generic frame
            // reader's consumption must be charged against the file).
            ensure!(remaining >= 4, "journal snapshot frame is missing its length prefix");
            f.read_exact(&mut u32buf)?;
            remaining -= 4;
            let len = u32::from_le_bytes(u32buf) as u64;
            ensure!(
                len <= remaining,
                "journal snapshot frame claims {len} bytes but only {remaining} remain"
            );
            let mut payload = Vec::with_capacity((len as usize).min(1 << 16));
            let got = Read::by_ref(&mut f).take(len).read_to_end(&mut payload)?;
            ensure!(got as u64 == len, "journal snapshot frame truncated");
            remaining -= len;
            let msg = wire::decode_payload(&payload).context("decode journal snapshot frame")?;
            let WireMsg::StateSnapOk(snap) = msg else {
                bail!("journal snapshot section holds an unexpected wire message");
            };
            Some(snap.entries)
        }
        n => bail!("journal snapshot flag {n} is neither 0 nor 1"),
    };
    ensure!(
        sync_t == 0 || snaps.is_some(),
        "journal sync at step {sync_t} carries no optimizer snapshot"
    );
    ensure!(remaining >= 4, "journal is missing the address count");
    f.read_exact(&mut u32buf)?;
    remaining -= 4;
    let n_addrs = u32::from_le_bytes(u32buf) as usize;
    ensure!(
        (n_addrs as u64) <= remaining / 4,
        "journal claims {n_addrs} addresses but only {remaining} bytes follow"
    );
    let mut addrs = Vec::with_capacity(n_addrs.min((remaining / 4) as usize));
    for k in 0..n_addrs {
        f.read_exact(&mut u32buf)?;
        remaining -= 4;
        let len = u32::from_le_bytes(u32buf) as u64;
        ensure!(len <= 4096, "journal address {k}: implausible length {len}");
        ensure!(
            len <= remaining,
            "journal address {k} claims {len} bytes but only {remaining} remain"
        );
        let mut bytes = vec![0u8; (len as usize).min(remaining as usize)];
        f.read_exact(&mut bytes)?;
        remaining -= len;
        addrs.push(
            String::from_utf8(bytes)
                .map_err(|_| anyhow::anyhow!("journal address {k} is not UTF-8"))?,
        );
    }
    // Step region: recover complete consecutive records; a parse
    // failure from here on is a torn tail, not an error.
    let mut steps: Vec<ReplayStep> = Vec::new();
    let mut torn = false;
    while remaining > 0 {
        let parse = |f: &mut std::io::BufReader<std::fs::File>,
                     remaining: &mut u64|
         -> Result<ReplayStep> {
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            *remaining -= 1;
            ensure!(tag[0] == REC_STEP, "unknown journal record tag {}", tag[0]);
            let mut u64buf = [0u8; 8];
            let mut u32buf = [0u8; 4];
            ensure!(*remaining >= 20, "step record header truncated");
            f.read_exact(&mut u64buf)?;
            let t = u64::from_le_bytes(u64buf);
            f.read_exact(&mut u64buf)?;
            let lr = f64::from_le_bytes(u64buf);
            f.read_exact(&mut u32buf)?;
            *remaining -= 20;
            let n = u32::from_le_bytes(u32buf) as usize;
            ensure!(
                (n as u64) <= *remaining / 8,
                "step record claims {n} gradients but only {remaining} bytes remain"
            );
            let mut grads = Vec::with_capacity(n.min((*remaining / 8) as usize));
            for k in 0..n {
                grads.push(read_tensor(f, remaining, &format!("journal step gradient {k}"))?);
            }
            Ok(ReplayStep { t, lr, grads })
        };
        match parse(&mut f, &mut remaining) {
            Ok(rec) => {
                let expect = sync_t + steps.len() as u64 + 1;
                if rec.t != expect {
                    torn = true;
                    break;
                }
                steps.push(rec);
            }
            Err(_) => {
                torn = true;
                break;
            }
        }
    }
    Ok(JournalContents { sync_t, params, snaps, addrs, steps, torn })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{EngineConfig, Optimizer, ShampooConfig, UnitKind};
    use crate::util::rng::Pcg64;

    fn tmp_path(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("{name}_{}", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    fn sample_params(seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        vec![Matrix::randn(3, 4, &mut rng), Matrix::randn(2, 2, &mut rng)]
    }

    /// Typed snapshot entries from a real sketched engine (the journal
    /// payload is the same object the wire `StateSnap` RPC ships).
    fn sketched_entries() -> Vec<BlockStateMsg> {
        let shapes = [(9usize, 6), (4, 4)];
        let base = ShampooConfig {
            start_preconditioning_step: 2,
            stat_interval: 1,
            precond_interval: 2,
            ..Default::default()
        };
        let ecfg =
            EngineConfig { threads: 1, block_size: 5, refresh_interval: 2, ..Default::default() };
        let mut eng = crate::optim::ExecutorBuilder::local()
            .build(&shapes, UnitKind::Sketched { rank: 3 }, base, ecfg)
            .unwrap();
        let mut rng = Pcg64::new(611);
        let mut params: Vec<Matrix> =
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
        for _ in 0..5 {
            let grads: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| Matrix::randn(r, c, &mut rng)).collect();
            eng.try_step(&mut params, &grads).unwrap();
        }
        eng.state_payloads().unwrap().expect("engine has typed state")
    }

    fn sample_journal(path: &str, sync_t: u64, n_steps: u64) -> (JournalContents, Vec<u64>) {
        let params = sample_params(700 + sync_t);
        let snaps = (sync_t > 0).then(sketched_entries);
        let addrs = vec!["127.0.0.1:4001".to_string(), String::new()];
        let mut w =
            JournalWriter::create(path, sync_t, &params, snaps.as_deref(), &addrs).unwrap();
        // Record the file size after the sync section and after every
        // appended record, so truncation tests know the boundaries.
        let mut boundaries = vec![std::fs::metadata(path).unwrap().len()];
        let mut rng = Pcg64::new(41 + sync_t);
        let mut steps = Vec::new();
        for k in 0..n_steps {
            let t = sync_t + 1 + k;
            let lr = 0.05 / (k + 1) as f64;
            let grads = vec![Matrix::randn(3, 4, &mut rng), Matrix::randn(2, 2, &mut rng)];
            w.append_step(t, lr, &grads).unwrap();
            boundaries.push(std::fs::metadata(path).unwrap().len());
            steps.push(ReplayStep { t, lr, grads });
        }
        let contents =
            JournalContents { sync_t, params, snaps, addrs, steps, torn: false };
        (contents, boundaries)
    }

    #[test]
    fn roundtrip_with_snapshot_and_steps() {
        let path = tmp_path("sketchy_journal_roundtrip.bin");
        let (want, _) = sample_journal(&path, 6, 3);
        let got = load_journal(&path).unwrap();
        assert_eq!(got, want);
        // Param and gradient payloads are bitwise, not approximate.
        for (a, b) in got.params.iter().zip(&want.params) {
            for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // No staging file left behind.
        let staged = format!("{path}.{}.tmp", std::process::id());
        assert!(!std::path::Path::new(&staged).exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sync_rewrite_discards_covered_steps_atomically() {
        let path = tmp_path("sketchy_journal_rewrite.bin");
        let (_, _) = sample_journal(&path, 0, 4);
        // A new sync point rewrites the whole file: the four old step
        // records are covered by the snapshot and vanish.
        let (want, _) = sample_journal(&path, 4, 1);
        let got = load_journal(&path).unwrap();
        assert_eq!(got, want);
        // A stale crashed staging file next to it changes nothing.
        let staged = format!("{path}.{}.tmp", std::process::id());
        std::fs::write(&staged, b"torn staging garbage").unwrap();
        assert_eq!(load_journal(&path).unwrap(), want);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&staged).ok();
    }

    #[test]
    fn every_byte_truncation_recovers_a_consistent_prefix() {
        // The crash-simulation sweep: truncate a journal with a real
        // snapshot and several appended steps at every byte boundary.
        // Cuts inside the atomically-published sync section must error
        // loudly; cuts in the append-only step region must recover
        // exactly the complete records before the cut and flag the torn
        // tail — never panic, never a giant allocation, never a record
        // past the cut.
        let path = tmp_path("sketchy_journal_trunc.bin");
        let (want, boundaries) = sample_journal(&path, 6, 3);
        let full = std::fs::read(&path).unwrap();
        assert_eq!(*boundaries.last().unwrap() as usize, full.len());
        let sync_len = boundaries[0];
        for cut in 0..full.len() as u64 {
            std::fs::write(&path, &full[..cut as usize]).unwrap();
            if cut < sync_len {
                assert!(
                    load_journal(&path).is_err(),
                    "sync-section prefix of {cut}/{} bytes must not load",
                    full.len()
                );
                continue;
            }
            let got = load_journal(&path)
                .unwrap_or_else(|e| panic!("step-region cut at {cut} failed: {e}"));
            let complete = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(got.steps.len(), complete, "cut at {cut}");
            assert_eq!(got.steps[..], want.steps[..complete], "cut at {cut}");
            assert_eq!(got.sync_t, want.sync_t);
            assert_eq!(got.params, want.params);
            assert_eq!(got.snaps, want.snaps);
            let at_boundary = boundaries.contains(&cut);
            assert_eq!(got.torn, !at_boundary, "cut at {cut}");
        }
        std::fs::write(&path, &full).unwrap();
        assert_eq!(load_journal(&path).unwrap(), want);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trailing_garbage_is_dropped_as_a_torn_tail() {
        let path = tmp_path("sketchy_journal_garbage.bin");
        let (want, _) = sample_journal(&path, 2, 2);
        let full = std::fs::read(&path).unwrap();
        // Pure garbage after the last complete record.
        let mut b = full.clone();
        b.extend_from_slice(&[0xEE; 37]);
        std::fs::write(&path, &b).unwrap();
        let got = load_journal(&path).unwrap();
        assert_eq!(got.steps, want.steps);
        assert!(got.torn);
        // A plausible-looking record with a non-consecutive step index
        // is dropped too (replay must stay contiguous from sync_t).
        let mut b = full.clone();
        b.push(REC_STEP);
        b.extend_from_slice(&99u64.to_le_bytes());
        b.extend_from_slice(&0.1f64.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        let got = load_journal(&path).unwrap();
        assert_eq!(got.steps, want.steps);
        assert!(got.torn);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn alloc_bomb_headers_are_rejected_or_dropped() {
        let path = tmp_path("sketchy_journal_bomb.bin");
        let header = |sync_t: u64, count: u32| {
            let mut b = Vec::new();
            b.extend_from_slice(MAGIC);
            b.extend_from_slice(&VERSION.to_le_bytes());
            b.extend_from_slice(&sync_t.to_le_bytes());
            b.extend_from_slice(&count.to_le_bytes());
            b
        };
        // Param-count lie in a header-only file.
        std::fs::write(&path, header(0, u32::MAX)).unwrap();
        assert!(load_journal(&path).is_err());
        // Param-shape lie.
        let mut b = header(0, 1);
        b.extend_from_slice(&(1u32 << 20).to_le_bytes());
        b.extend_from_slice(&(1u32 << 20).to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        // Snapshot-frame length lie.
        let mut b = header(0, 0);
        b.push(1);
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        // A nonzero sync point without a snapshot is refused.
        let mut b = header(9, 0);
        b.push(0);
        b.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        // Address-length lie.
        let mut b = header(0, 0);
        b.push(0);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        // Wrong wire message in the snapshot slot.
        let mut b = header(0, 0);
        b.push(1);
        wire::write_msg(&mut b, &WireMsg::Ok).unwrap();
        b.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        // A gradient-count lie inside a *step* record is a torn tail
        // (append region), recovered as zero steps — not an error, and
        // not an allocation.
        let (want, _) = sample_journal(&path, 0, 0);
        let mut b = std::fs::read(&path).unwrap();
        b.push(REC_STEP);
        b.extend_from_slice(&1u64.to_le_bytes());
        b.extend_from_slice(&0.1f64.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        let got = load_journal(&path).unwrap();
        assert_eq!(got.params, want.params);
        assert!(got.steps.is_empty());
        assert!(got.torn);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_and_wrong_magic() {
        let path = tmp_path("sketchy_journal_notone.bin");
        std::fs::write(&path, b"not a journal").unwrap();
        assert!(load_journal(&path).is_err());
        // A checkpoint is not a journal.
        let mut b = Vec::new();
        b.extend_from_slice(b"SKCH");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&0u64.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &b).unwrap();
        assert!(load_journal(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
