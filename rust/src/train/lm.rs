//! Transformer-LM trainer (E10, the end-to-end driver): PJRT gradient
//! artifact + Markov corpus + Rust optimizer + data-parallel coordinator.

use super::artifact_worker::{init_params_from_specs, params_to_f32, ArtifactGradWorker, InputBuf};
use super::metrics::CurveLog;
use crate::coordinator::data_parallel_step;
use crate::data::MarkovCorpus;
use crate::optim::Optimizer;
use crate::runtime::literal::{lit_f32, lit_i32, lit_scalar};
use crate::runtime::Runtime;
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Trainer state for one LM preset.
pub struct LmTrainer {
    pub runtime: Arc<Runtime>,
    pub grad_artifact: String,
    pub eval_artifact: String,
    pub names: Vec<String>,
    pub shapes: Vec<(usize, usize)>,
    pub params: Vec<Matrix>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    step: usize,
}

impl LmTrainer {
    /// Build from the manifest; `preset` must match an exported artifact
    /// pair (`lm_<preset>_grad` / `lm_<preset>_eval`).
    pub fn new(runtime: Arc<Runtime>, preset: &str, seed: u64) -> Result<Self> {
        let grad_artifact = format!("lm_{preset}_grad");
        let eval_artifact = format!("lm_{preset}_eval");
        let spec = runtime
            .spec(&grad_artifact)
            .ok_or_else(|| anyhow!("artifact {grad_artifact} not in manifest"))?
            .clone();
        let (names, shapes, params) =
            init_params_from_specs(&spec.inputs, spec.n_params, seed);
        let tok = &spec.inputs[spec.n_params];
        anyhow::ensure!(tok.name == "tokens" && tok.shape.len() == 2);
        let batch = tok.shape[0];
        let seq = tok.shape[1] - 1;
        let vocab = shapes[0].0; // embed rows
        Ok(LmTrainer {
            runtime,
            grad_artifact,
            eval_artifact,
            names,
            shapes,
            params,
            batch,
            seq,
            vocab,
            step: 0,
        })
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.shapes.iter().map(|&(r, c)| r * c).sum()
    }

    fn sample_tokens(&self, corpus: &mut MarkovCorpus) -> InputBuf {
        let rows = corpus.batch(self.batch, self.seq);
        let flat: Vec<i32> = rows
            .into_iter()
            .flatten()
            .map(|t| (t as usize % self.vocab) as i32)
            .collect();
        InputBuf::I32(flat, vec![self.batch, self.seq + 1])
    }

    /// One data-parallel training step; returns (mean loss, mean grads —
    /// post-allreduce, pre-optimizer — for spectral hooks).
    pub fn step(
        &mut self,
        opt: &mut dyn Optimizer,
        corpus: &mut MarkovCorpus,
        workers: usize,
    ) -> Result<(f64, Vec<Matrix>)> {
        let param_bufs = params_to_f32(&self.params);
        let batches: Vec<Vec<InputBuf>> = (0..workers)
            .map(|_| vec![self.sample_tokens(corpus)])
            .collect();
        let gw = ArtifactGradWorker {
            runtime: &self.runtime,
            artifact: &self.grad_artifact,
            param_bufs: &param_bufs,
            shapes: &self.shapes,
            batches: &batches,
        };
        let res = data_parallel_step(&gw, self.step, workers)?;
        // Fallible path: a sharded engine's worker/transport failure
        // surfaces here as an error naming the shard, not a panic.
        opt.try_step(&mut self.params, &res.grads)?;
        self.step += 1;
        Ok((res.loss, res.grads))
    }

    /// Held-out evaluation loss on `n_batches` fresh batches.
    pub fn eval(&self, corpus: &mut MarkovCorpus, n_batches: usize) -> Result<f64> {
        let param_bufs = params_to_f32(&self.params);
        let mut total = 0.0;
        for _ in 0..n_batches {
            let mut inputs = Vec::with_capacity(self.params.len() + 1);
            for (buf, &(r, c)) in param_bufs.iter().zip(&self.shapes) {
                inputs.push(lit_f32(buf, &[r, c])?);
            }
            match self.sample_tokens(corpus) {
                InputBuf::I32(data, shape) => inputs.push(lit_i32(&data, &shape)?),
                _ => unreachable!(),
            }
            let outs = self.runtime.execute(&self.eval_artifact, &inputs)?;
            total += lit_scalar(&outs[0])?;
        }
        Ok(total / n_batches as f64)
    }

    /// Full training run: returns the loss curve.
    pub fn train(
        &mut self,
        opt: &mut dyn Optimizer,
        corpus: &mut MarkovCorpus,
        steps: usize,
        workers: usize,
        schedule: Option<crate::optim::WarmupCosine>,
        log_every: usize,
    ) -> Result<CurveLog> {
        let mut curve = CurveLog::new(&opt.name());
        for s in 0..steps {
            if let Some(sch) = schedule {
                opt.set_lr(sch.at(s));
            }
            let (loss, _) = self.step(opt, corpus, workers)?;
            if s % log_every.max(1) == 0 || s + 1 == steps {
                curve.push(s, loss);
            }
        }
        Ok(curve)
    }
}
