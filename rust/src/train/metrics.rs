//! Metric curve recording and report generation (EXPERIMENTS.md tables
//! are produced from these).

use std::fmt::Write as _;

/// A named (step, value) curve.
#[derive(Clone, Debug, Default)]
pub struct CurveLog {
    pub name: String,
    pub points: Vec<(usize, f64)>,
}

impl CurveLog {
    pub fn new(name: &str) -> Self {
        CurveLog { name: name.to_string(), points: vec![] }
    }

    pub fn push(&mut self, step: usize, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` recorded values (smoothed terminal metric).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.points.len();
        if n == 0 {
            return f64::NAN;
        }
        let k = k.min(n);
        self.points[n - k..].iter().map(|&(_, v)| v).sum::<f64>() / k as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,value\n");
        for &(t, v) in &self.points {
            let _ = writeln!(s, "{t},{v}");
        }
        s
    }
}

/// Render several curves as a markdown table sampled at shared steps.
pub fn curves_to_markdown(curves: &[&CurveLog], sample_every: usize) -> String {
    let mut s = String::from("| step |");
    for c in curves {
        let _ = write!(s, " {} |", c.name);
    }
    s.push('\n');
    s.push_str("|---|");
    for _ in curves {
        s.push_str("---|");
    }
    s.push('\n');
    let max_len = curves.iter().map(|c| c.points.len()).max().unwrap_or(0);
    for i in (0..max_len).step_by(sample_every.max(1)) {
        if let Some(&(step, _)) = curves[0].points.get(i) {
            let _ = write!(s, "| {step} |");
            for c in curves {
                match c.points.get(i) {
                    Some(&(_, v)) => {
                        let _ = write!(s, " {v:.4} |");
                    }
                    None => {
                        let _ = write!(s, " — |");
                    }
                }
            }
            s.push('\n');
        }
    }
    s
}

/// Write a string to a file, creating parent directories.
pub fn write_report(path: &str, content: &str) -> anyhow::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_basics() {
        let mut c = CurveLog::new("loss");
        c.push(0, 4.0);
        c.push(10, 2.0);
        c.push(20, 1.0);
        assert_eq!(c.last(), Some(1.0));
        assert_eq!(c.tail_mean(2), 1.5);
        assert!(c.to_csv().contains("10,2"));
    }

    #[test]
    fn markdown_table() {
        let mut a = CurveLog::new("adam");
        let mut b = CurveLog::new("s-shampoo");
        for i in 0..5 {
            a.push(i, i as f64);
            b.push(i, 2.0 * i as f64);
        }
        let md = curves_to_markdown(&[&a, &b], 2);
        assert!(md.contains("| step | adam | s-shampoo |"));
        assert!(md.contains("| 2 | 2.0000 | 4.0000 |"));
    }

    #[test]
    fn empty_tail_mean_is_nan() {
        assert!(CurveLog::new("x").tail_mean(3).is_nan());
    }
}
