//! Training loop (system S9): drives the PJRT gradient artifacts with the
//! Rust optimizer family through the data-parallel coordinator.

pub mod artifact_worker;
pub mod checkpoint;
pub mod journal;
pub mod lm;
pub mod metrics;
pub mod proxy_train;

pub use checkpoint::{
    load_checkpoint, load_checkpoint_full, save_checkpoint, save_checkpoint_with_state,
};
pub use journal::{load_journal, JournalContents, JournalWriter, ReplayStep};
pub use lm::LmTrainer;
pub use metrics::CurveLog;
pub use proxy_train::{ProxyTask, ProxyTrainer};
