//! Trainers for the three Fig. 2 proxy tasks (E3/E9): image CNN, audio
//! conformer block, molecular GNN — each driven through its PJRT
//! artifact with any Rust optimizer.

use super::artifact_worker::{init_params_from_specs, params_to_f32, ArtifactGradWorker, InputBuf};
use super::metrics::CurveLog;
use crate::coordinator::data_parallel_step;
use crate::data::proxy::{AudioProxy, GraphProxy, ImageProxy};
use crate::optim::Optimizer;
use crate::runtime::Runtime;
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Which Fig. 2 task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProxyTask {
    Image,
    Audio,
    Graph,
}

impl ProxyTask {
    pub fn name(&self) -> &'static str {
        match self {
            ProxyTask::Image => "image",
            ProxyTask::Audio => "audio",
            ProxyTask::Graph => "graph",
        }
    }

    pub fn grad_artifact(&self) -> &'static str {
        match self {
            ProxyTask::Image => "cnn_grad",
            ProxyTask::Audio => "conformer_grad",
            ProxyTask::Graph => "gnn_grad",
        }
    }

    pub fn eval_artifact(&self) -> &'static str {
        match self {
            ProxyTask::Image => "cnn_eval",
            ProxyTask::Audio => "conformer_eval",
            ProxyTask::Graph => "gnn_eval",
        }
    }

    /// The paper's test metric analogue: classification error for
    /// image/audio (ImageNet error rate / WER stand-ins), mean per-task
    /// binary error for graph (1 − AP stand-in).
    pub fn metric_name(&self) -> &'static str {
        match self {
            ProxyTask::Image => "error rate",
            ProxyTask::Audio => "error rate",
            ProxyTask::Graph => "multi-task error",
        }
    }
}

/// Stateful per-task batch generator (seeded).
enum Gen {
    Image(ImageProxy),
    Audio(AudioProxy),
    Graph(GraphProxy),
}

/// Proxy-task trainer.
pub struct ProxyTrainer {
    pub runtime: Arc<Runtime>,
    pub task: ProxyTask,
    pub names: Vec<String>,
    pub shapes: Vec<(usize, usize)>,
    pub params: Vec<Matrix>,
    batch: usize,
    gen: Gen,
    /// Held-out generator for eval (different seed stream).
    eval_gen: Gen,
    step: usize,
}

// Python-side configs mirrored (python/compile/models_proxy.py).
const IMG: (usize, usize, usize) = (16, 16, 8); // h, w, classes
const AUD: (usize, usize, usize) = (16, 32, 8); // frames, bins, classes
const GNN: (usize, usize, usize) = (16, 8, 8); // nodes, feat, tasks

impl ProxyTrainer {
    pub fn new(runtime: Arc<Runtime>, task: ProxyTask, seed: u64) -> Result<Self> {
        let spec = runtime
            .spec(task.grad_artifact())
            .ok_or_else(|| anyhow!("artifact {} not in manifest", task.grad_artifact()))?
            .clone();
        let (names, shapes, params) =
            init_params_from_specs(&spec.inputs, spec.n_params, seed);
        let batch = spec.inputs[spec.n_params].shape[0];
        // Held-out eval shares the *task definition* (class templates /
        // state bands) but draws an independent sample stream; graph
        // labels derive from each sampled graph, so a fresh seed suffices.
        let (gen, eval_gen) = match task {
            ProxyTask::Image => {
                let g = ImageProxy::new(IMG.0, IMG.1, IMG.2, seed);
                let e = g.fork_stream(seed ^ 0xeeee);
                (Gen::Image(g), Gen::Image(e))
            }
            ProxyTask::Audio => {
                let g = AudioProxy::new(AUD.0, AUD.1, AUD.2, seed);
                let e = g.fork_stream(seed ^ 0xeeee);
                (Gen::Audio(g), Gen::Audio(e))
            }
            ProxyTask::Graph => (
                Gen::Graph(GraphProxy::new(GNN.0, GNN.1, GNN.2, seed)),
                Gen::Graph(GraphProxy::new(GNN.0, GNN.1, GNN.2, seed ^ 0xeeee)),
            ),
        };
        Ok(ProxyTrainer {
            runtime,
            task,
            names,
            shapes,
            params,
            batch,
            gen,
            eval_gen,
            step: 0,
        })
    }

    fn sample(gen: &mut Gen, batch: usize) -> (Vec<InputBuf>, Vec<i32>, Vec<f32>) {
        match gen {
            Gen::Image(p) => {
                let b = p.batch(batch);
                let bufs = vec![
                    InputBuf::F32(b.features.clone(), vec![batch, b.feature_len]),
                    InputBuf::I32(b.labels.clone(), vec![batch]),
                ];
                (bufs, b.labels, vec![])
            }
            Gen::Audio(p) => {
                let b = p.batch(batch);
                let bufs = vec![
                    InputBuf::F32(b.features.clone(), vec![batch, b.feature_len]),
                    InputBuf::I32(b.labels.clone(), vec![batch]),
                ];
                (bufs, b.labels, vec![])
            }
            Gen::Graph(p) => {
                let b = p.batch(batch);
                let nn = GNN.0;
                let bufs = vec![
                    InputBuf::F32(b.adjacency.clone(), vec![batch, nn * nn]),
                    InputBuf::F32(b.features.clone(), vec![batch, nn * GNN.1]),
                    InputBuf::F32(b.labels.clone(), vec![batch, GNN.2]),
                ];
                (bufs, vec![], b.labels)
            }
        }
    }

    /// Build a parallel block-engine optimizer over this trainer's
    /// parameter shapes (`engine-adam` | `engine-shampoo` |
    /// `engine-s-shampoo`): data-parallel gradient workers upstream,
    /// block-parallel preconditioning downstream — the §7 amortization
    /// stacked end to end.
    pub fn engine_optimizer(
        &self,
        name: &str,
        base: crate::optim::ShampooConfig,
        rank: usize,
        ecfg: crate::optim::EngineConfig,
    ) -> Result<crate::optim::PrecondEngine> {
        crate::optim::engine_optimizer(name, &self.shapes, base, rank, ecfg)
            .ok_or_else(|| anyhow!("unknown engine optimizer {name}"))
    }

    /// One data-parallel step; returns (loss, allreduced grads).
    pub fn step(
        &mut self,
        opt: &mut dyn Optimizer,
        workers: usize,
    ) -> Result<(f64, Vec<Matrix>)> {
        let param_bufs = params_to_f32(&self.params);
        let batches: Vec<Vec<InputBuf>> = (0..workers)
            .map(|_| Self::sample(&mut self.gen, self.batch).0)
            .collect();
        let gw = ArtifactGradWorker {
            runtime: &self.runtime,
            artifact: self.task.grad_artifact(),
            param_bufs: &param_bufs,
            shapes: &self.shapes,
            batches: &batches,
        };
        let res = data_parallel_step(&gw, self.step, workers)?;
        // Fallible path: a sharded engine's worker/transport failure
        // surfaces here as an error naming the shard, not a panic.
        opt.try_step(&mut self.params, &res.grads)?;
        self.step += 1;
        Ok((res.loss, res.grads))
    }

    /// Held-out (loss, metric) over `n_batches` eval batches.
    pub fn eval(&mut self, n_batches: usize) -> Result<(f64, f64)> {
        let param_bufs = params_to_f32(&self.params);
        let mut loss_total = 0.0;
        let mut err_total = 0.0;
        for _ in 0..n_batches {
            let (bufs, int_labels, f32_labels) = Self::sample(&mut self.eval_gen, self.batch);
            let mut inputs = Vec::with_capacity(self.params.len() + bufs.len());
            for (buf, &(r, c)) in param_bufs.iter().zip(&self.shapes) {
                inputs.push(crate::runtime::literal::lit_f32(buf, &[r, c])?);
            }
            for b in &bufs {
                inputs.push(b.to_literal()?);
            }
            let outs = self.runtime.execute(self.task.eval_artifact(), &inputs)?;
            loss_total += crate::runtime::literal::lit_scalar(&outs[0])?;
            let logits = crate::runtime::literal::lit_to_f64(&outs[1])?;
            err_total += match self.task {
                ProxyTask::Image | ProxyTask::Audio => {
                    let classes = logits.len() / self.batch;
                    let mut errs = 0usize;
                    for (i, &lab) in int_labels.iter().enumerate() {
                        let row = &logits[i * classes..(i + 1) * classes];
                        let argmax = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0;
                        if argmax as i32 != lab {
                            errs += 1;
                        }
                    }
                    errs as f64 / self.batch as f64
                }
                ProxyTask::Graph => {
                    let mut errs = 0usize;
                    for (i, &lab) in f32_labels.iter().enumerate() {
                        let pred = if logits[i] > 0.0 { 1.0 } else { 0.0 };
                        if (pred - lab as f64).abs() > 0.5 {
                            errs += 1;
                        }
                    }
                    errs as f64 / f32_labels.len() as f64
                }
            };
        }
        Ok((loss_total / n_batches as f64, err_total / n_batches as f64))
    }

    /// Train with periodic eval; returns (train-loss curve, metric curve).
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &mut self,
        opt: &mut dyn Optimizer,
        steps: usize,
        workers: usize,
        schedule: Option<crate::optim::WarmupCosine>,
        eval_every: usize,
        eval_batches: usize,
        mut grad_hook: Option<&mut dyn FnMut(usize, &[Matrix])>,
    ) -> Result<(CurveLog, CurveLog)> {
        let mut train_curve = CurveLog::new(&format!("{}/train", opt.name()));
        let mut metric_curve = CurveLog::new(&format!("{}/metric", opt.name()));
        for s in 0..steps {
            if let Some(sch) = schedule {
                opt.set_lr(sch.at(s));
            }
            let (loss, grads) = self.step(opt, workers)?;
            if let Some(hook) = grad_hook.as_deref_mut() {
                hook(s, &grads);
            }
            train_curve.push(s, loss);
            if s % eval_every.max(1) == 0 || s + 1 == steps {
                let (_eval_loss, metric) = self.eval(eval_batches)?;
                metric_curve.push(s, metric);
            }
        }
        Ok((train_curve, metric_curve))
    }
}
