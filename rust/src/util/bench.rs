//! Wall-clock micro-benchmark harness (criterion is not vendored).
//!
//! Usage pattern (see `rust/benches/bench_main.rs`):
//! ```no_run
//! use sketchy::util::bench::Bench;
//! let mut b = Bench::new("matmul_256");
//! b.run(|| { /* workload */ });
//! println!("{}", b.report());
//! ```
//! Runs a warmup phase, then timed repetitions until a time or count
//! budget is hit, and reports median / p10 / p90 / mean.

use std::time::{Duration, Instant};

/// One benchmark: name + collected per-iteration timings.
pub struct Bench {
    pub name: String,
    samples: Vec<Duration>,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations.
    pub max_iters: usize,
    /// Wall-clock budget for the measurement phase.
    pub budget: Duration,
    /// Warmup iterations.
    pub warmup: usize,
}

/// Summary statistics for a finished benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub min: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            samples: vec![],
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(2),
            warmup: 2,
        }
    }

    /// Quick-profile configuration (used under `--fast`).
    pub fn fast(name: &str) -> Self {
        let mut b = Bench::new(name);
        b.min_iters = 3;
        b.max_iters = 20;
        b.budget = Duration::from_millis(300);
        b.warmup = 1;
        b
    }

    /// Run the workload under the harness.
    pub fn run<F: FnMut()>(&mut self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        while self.samples.len() < self.min_iters
            || (start.elapsed() < self.budget && self.samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            self.samples.push(t0.elapsed());
        }
        self.stats()
    }

    /// Statistics over collected samples.
    pub fn stats(&self) -> Stats {
        let mut s = self.samples.clone();
        s.sort();
        let n = s.len();
        assert!(n > 0, "no samples");
        let total: Duration = s.iter().sum();
        Stats {
            iters: n,
            mean: total / n as u32,
            median: s[n / 2],
            p10: s[n / 10],
            p90: s[(n * 9) / 10],
            min: s[0],
        }
    }

    /// Human-readable one-line report.
    pub fn report(&self) -> String {
        let st = self.stats();
        format!(
            "{:<40} iters={:<4} median={:>12?} p10={:>12?} p90={:>12?} mean={:>12?}",
            self.name, st.iters, st.median, st.p10, st.p90, st.mean
        )
    }

    /// CSV row: name,iters,median_ns,p10_ns,p90_ns,mean_ns.
    pub fn csv_row(&self) -> String {
        let st = self.stats();
        format!(
            "{},{},{},{},{},{}",
            self.name,
            st.iters,
            st.median.as_nanos(),
            st.p10.as_nanos(),
            st.p90.as_nanos(),
            st.mean.as_nanos()
        )
    }
}

/// Format a throughput given work per iteration and a duration.
pub fn gflops(flops_per_iter: f64, time: Duration) -> f64 {
    flops_per_iter / time.as_secs_f64() / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_and_reports() {
        let mut b = Bench::fast("noop");
        let st = b.run(|| {
            std::hint::black_box(1 + 1);
        });
        assert!(st.iters >= 3);
        assert!(st.p10 <= st.median && st.median <= st.p90);
        assert!(b.report().contains("noop"));
        assert_eq!(b.csv_row().split(',').count(), 6);
    }

    #[test]
    fn gflops_math() {
        let g = gflops(2e9, Duration::from_secs(1));
        assert!((g - 2.0).abs() < 1e-12);
    }
}
