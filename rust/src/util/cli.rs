//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `subcommand --flag value --bool-flag positional` style
//! invocations with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare
/// `--switch` flags, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    ///
    /// Grammar note: a token after `--flag` that does not itself start
    /// with `--` is taken as that flag's value, so positional arguments
    /// should precede flags (or use `--flag=value`).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                args.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // Value if next token exists and isn't a flag.
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(name.to_string(), v);
                        }
                        _ => args.switches.push(name.to_string()),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Boolean flag: a bare `--flag` switch means true; `--flag true|1|
    /// yes|on` / `--flag false|0|no|off` (or `--flag=...`) parse
    /// explicitly; absent means `default`. Unrecognized values warn
    /// loudly instead of being silently ignored.
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        if self.has(key) {
            return true;
        }
        match self.get(key) {
            Some(v) => match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" | "on" => true,
                "0" | "false" | "no" | "off" => false,
                other => {
                    eprintln!("warning: --{key} expects a boolean, got {other:?}; using {default}");
                    default
                }
            },
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "file.toml", "--steps", "100", "--lr=0.1", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.1);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.has("help"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["x", "--offset", "-3"]);
        assert_eq!(a.get_f64("offset", 0.0), -3.0);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("name", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn shard_flags_parse() {
        // The exact grammar the sharded-train entry point relies on.
        let a = parse(&["train", "--shards", "2", "--shard-transport", "unix"]);
        assert_eq!(a.get_usize("shards", 0), 2);
        assert_eq!(a.get("shard-transport"), Some("unix"));
        // And the worker side's own command line.
        let w = parse(&["shard-worker", "--worker-id", "1", "--transport", "tcp"]);
        assert_eq!(w.subcommand.as_deref(), Some("shard-worker"));
        assert_eq!(w.get_usize("worker-id", 99), 1);
        assert_eq!(w.get_or("transport", "unix"), "tcp");
    }

    #[test]
    fn shard_proto_flags_parse() {
        // The version-handshake knobs: the driver's --shard-proto and
        // the worker's --proto-version (passed through on spawn).
        let a = parse(&["train", "--shards", "2", "--shard-proto", "1"]);
        assert_eq!(a.get_usize("shard-proto", 2), 1);
        let d = parse(&["train", "--shards", "2"]);
        assert_eq!(d.get_usize("shard-proto", 2), 2); // defaults apply
        let w = parse(&["shard-worker", "--worker-id", "0", "--proto-version", "1"]);
        assert_eq!(w.get_usize("proto-version", 2), 1);
    }

    #[test]
    fn shard_compress_and_launch_flags_parse() {
        // The v3 payload-layer knob and the multi-host launcher
        // template (quoted as one argv word by the shell).
        let a = parse(&[
            "train",
            "--shards",
            "2",
            "--shard-compress",
            "false",
            "--shard-launch",
            "ssh worker-{shard} /opt/sketchy/sketchy {worker_cmd}",
        ]);
        assert!(!a.get_bool("shard-compress", true));
        assert_eq!(
            a.get("shard-launch"),
            Some("ssh worker-{shard} /opt/sketchy/sketchy {worker_cmd}")
        );
        // Worker-side multi-host flags.
        let w = parse(&[
            "shard-worker",
            "--worker-id",
            "0",
            "--listen",
            "0.0.0.0:0",
            "--advertise-host",
            "worker-0.cluster",
        ]);
        assert_eq!(w.get_or("listen", "127.0.0.1:0"), "0.0.0.0:0");
        assert_eq!(w.get("advertise-host"), Some("worker-0.cluster"));
    }

    #[test]
    fn supervision_and_journal_flags_parse() {
        // The exact grammar the durable-driver entry point relies on:
        // link-timeout knobs, the write-ahead journal pair, and the
        // crash-harness step list.
        let a = parse(&[
            "train",
            "--shards",
            "2",
            "--shard-connect-timeout-ms",
            "2000",
            "--shard-reply-timeout-ms",
            "30000",
            "--shard-heartbeat-ms",
            "250",
            "--shard-deadline-ms",
            "5000",
            "--journal",
            "out/wal.skjl",
            "--crash-at-step",
            "3,7",
        ]);
        assert_eq!(a.get_u64("shard-connect-timeout-ms", 0), 2000);
        assert_eq!(a.get_u64("shard-reply-timeout-ms", 0), 30_000);
        assert_eq!(a.get_u64("shard-heartbeat-ms", 0), 250);
        assert_eq!(a.get_u64("shard-deadline-ms", 0), 5000);
        assert_eq!(a.get("journal"), Some("out/wal.skjl"));
        assert_eq!(a.get("crash-at-step"), Some("3,7"));
        let r = parse(&["train", "--resume-journal", "out/wal.skjl"]);
        assert_eq!(r.get("resume-journal"), Some("out/wal.skjl"));
        // An explicit empty value (clearing a config-file path) stays a
        // value, not a switch.
        let c = parse(&["train", "--journal", ""]);
        assert_eq!(c.get("journal"), Some(""));
    }

    #[test]
    fn pool_and_overlap_flags_parse() {
        // The exact grammar the engine runtime knobs rely on.
        let a = parse(&["train", "--pool-threads", "6", "--overlap-refresh"]);
        assert_eq!(a.get_usize("pool-threads", 0), 6);
        assert!(a.get_bool("overlap-refresh", false));
        let b = parse(&["train", "--overlap-refresh", "false"]);
        assert!(!b.get_bool("overlap-refresh", true));
    }

    #[test]
    fn ekfac_flag_parses() {
        // The exact grammar the EKFAC knob relies on: bare switch,
        // explicit two-token boolean (the spelling that overrides a
        // config-file `ekfac = true`), and `=` form.
        let a = parse(&["train", "--ekfac"]);
        assert!(a.get_bool("ekfac", false));
        let b = parse(&["train", "--ekfac", "false"]);
        assert!(!b.get_bool("ekfac", true));
        let c = parse(&["train", "--ekfac=true", "--steps", "50"]);
        assert!(c.get_bool("ekfac", false));
        assert_eq!(c.get_usize("steps", 0), 50);
        let d = parse(&["train"]);
        assert!(!d.get_bool("ekfac", false)); // absent means default
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["x", "--stagger-refresh", "--fresh", "false", "--stale=true"]);
        assert!(a.get_bool("stagger-refresh", false));
        assert!(!a.get_bool("fresh", true));
        assert!(a.get_bool("stale", false));
        assert!(a.get_bool("absent", true));
        assert!(!a.get_bool("absent", false));
        // Common non-Rust spellings parse too; garbage falls to default.
        let b = parse(&["x", "--off-flag", "0", "--on-flag", "yes", "--bad", "maybe"]);
        assert!(!b.get_bool("off-flag", true));
        assert!(b.get_bool("on-flag", false));
        assert!(b.get_bool("bad", true));
        assert!(!b.get_bool("bad", false));
    }
}
