//! TOML-subset configuration parser (system S11).
//!
//! The launcher reads experiment/training configs from simple TOML files:
//! `[section]` headers, `key = value` pairs with string / number / bool /
//! flat-array values, `#` comments. That subset covers every config this
//! repository ships; nested tables and multi-line values are rejected
//! loudly rather than mis-parsed.
//!
//! Canonical sections consumed by the launcher:
//! - `[train]` — `preset`, `steps`, `workers`, `lr`, `optimizer`
//! - `[s_shampoo]` — `rank`, `beta2`, `weight_decay`, `clip`,
//!   `stat_interval`, `precond_interval`, `graft`, `one_sided`
//! - `[engine]` — parallel block-engine knobs: `threads` (0 = auto),
//!   `block_size` (0 = one block per tensor), `refresh_interval`
//!   (stale-preconditioner eigendecomposition cadence),
//!   `stagger_refresh` (spread refreshes across blocks),
//!   `overlap_refresh` (pipeline next-step refreshes behind gradient
//!   computation), `pool_threads` (pre-size the persistent worker
//!   pool; 0 = grow on demand), `ekfac` (EKFAC-style inter-refresh
//!   corrections in the stale eigenbasis); see
//!   [`crate::optim::EngineConfig::resolve`]
//! - `[shard]` — cross-process engine sharding: `count` (worker
//!   processes, 0 = in-process), `transport` (`"tcp"` or `"unix"`),
//!   `proto` (wire protocol version workers speak; pin to 1 for the
//!   legacy pre-RefreshAhead handshake, which degrades sharded refresh
//!   overlap to synchronous, or 2 for the pre-compression handshake,
//!   which degrades payloads to full frames), `compress` (v3
//!   delta-compressed block payloads, default true), and `launch`
//!   (multi-host worker launcher command template with `{shard}` /
//!   `{program}` / `{worker_cmd}` placeholders, e.g. ssh); see
//!   [`crate::coordinator::ShardConfig::resolve`]

use std::collections::BTreeMap;
use std::fmt;

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key -> value`; keys before any `[section]`
/// live in the "" section.
#[derive(Debug, Default, Clone)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

/// Config parse error with line number.
#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for ConfigError {}

impl Config {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                if name.contains('[') || name.contains(']') {
                    return Err(ConfigError {
                        line: ln + 1,
                        msg: "nested tables are not supported".into(),
                    });
                }
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError {
                line: ln + 1,
                msg: format!("expected key = value, got: {line}"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim()).map_err(|msg| ConfigError { line: ln + 1, msg })?;
            cfg.map.insert(key, value);
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Config::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Override a value (CLI flags beat config files).
    pub fn set(&mut self, key: &str, value: Value) {
        self.map.insert(key.to_string(), value);
    }

    /// All keys under a section prefix.
    pub fn section_keys(&self, section: &str) -> Vec<String> {
        let prefix = format!("{section}.");
        self.map
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .cloned()
            .collect()
    }

    /// Refuse keys the `[section]` consumer does not understand. A
    /// typo'd knob — `overlap_refres` for `overlap_refresh` — must be
    /// a named error, never a silent fall-through to the default, so
    /// every section resolver calls this before reading its keys.
    pub fn ensure_known_keys(&self, section: &str, known: &[&str]) -> anyhow::Result<()> {
        for key in self.section_keys(section) {
            let bare = key
                .strip_prefix(section)
                .and_then(|k| k.strip_prefix('.'))
                .unwrap_or(&key);
            anyhow::ensure!(
                known.contains(&bare),
                "unknown [{section}] config key {key:?} (known keys: {})",
                known.join(", ")
            );
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut vals = vec![];
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                vals.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(vals));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let cfg = Config::parse(
            r#"
            # top comment
            name = "run1"
            [train]
            steps = 100     # trailing comment
            lr = 1e-3
            use_fd = true
            ranks = [4, 16, 64]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str_or("name", ""), "run1");
        assert_eq!(cfg.usize_or("train.steps", 0), 100);
        assert_eq!(cfg.f64_or("train.lr", 0.0), 1e-3);
        assert!(cfg.bool_or("train.use_fd", false));
        match cfg.get("train.ranks").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn hash_inside_string() {
        let cfg = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(cfg.str_or("tag", ""), "a#b");
    }

    #[test]
    fn errors_carry_lines() {
        let err = Config::parse("x = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(Config::parse("[a.b\n").is_err());
    }

    #[test]
    fn overrides() {
        let mut cfg = Config::parse("a = 1").unwrap();
        cfg.set("a", Value::Num(2.0));
        assert_eq!(cfg.f64_or("a", 0.0), 2.0);
    }

    #[test]
    fn section_key_listing() {
        let cfg = Config::parse("[s]\na = 1\nb = 2\n[t]\nc = 3").unwrap();
        assert_eq!(cfg.section_keys("s"), vec!["s.a", "s.b"]);
    }

    #[test]
    fn shard_section_round_trips() {
        let cfg = Config::parse(
            "[shard]\ncount = 2\ntransport = \"unix\"\nproto = 1\ncompress = false\n\
             launch = \"ssh w{shard} /opt/sketchy {worker_cmd}\"\n\
             connect_timeout_ms = 2000\nreply_timeout_ms = 30000\n\
             heartbeat_ms = 250\ndeadline_ms = 5000\njournal = \"out/wal.skjl\"",
        )
        .unwrap();
        assert_eq!(cfg.usize_or("shard.count", 0), 2);
        assert_eq!(cfg.str_or("shard.transport", "tcp"), "unix");
        assert_eq!(cfg.usize_or("shard.proto", 2), 1);
        assert!(!cfg.bool_or("shard.compress", true));
        assert_eq!(
            cfg.str_or("shard.launch", ""),
            "ssh w{shard} /opt/sketchy {worker_cmd}"
        );
        assert_eq!(cfg.usize_or("shard.connect_timeout_ms", 0), 2000);
        assert_eq!(cfg.usize_or("shard.reply_timeout_ms", 0), 30_000);
        assert_eq!(cfg.usize_or("shard.heartbeat_ms", 0), 250);
        assert_eq!(cfg.usize_or("shard.deadline_ms", 0), 5000);
        assert_eq!(cfg.str_or("shard.journal", ""), "out/wal.skjl");
        // Defaults apply when the section is absent.
        let empty = Config::default();
        assert_eq!(empty.usize_or("shard.count", 0), 0);
        assert_eq!(empty.str_or("shard.transport", "tcp"), "tcp");
        assert_eq!(empty.usize_or("shard.proto", 2), 2);
        assert!(empty.bool_or("shard.compress", true));
        assert_eq!(empty.str_or("shard.launch", ""), "");
        assert_eq!(empty.usize_or("shard.heartbeat_ms", 500), 500);
        assert_eq!(empty.str_or("shard.journal", ""), "");
    }

    #[test]
    fn known_key_validation_names_the_offender() {
        let cfg = Config::parse("[engine]\noverlap_refres = true\n[shard]\ncount = 2").unwrap();
        let err = cfg
            .ensure_known_keys("engine", &["threads", "overlap_refresh"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("overlap_refres"), "error must name the bad key: {err}");
        assert!(err.contains("overlap_refresh"), "error must list known keys: {err}");
        assert!(err.contains("[engine]"), "error must name the section: {err}");
        // Keys in other sections never trip a section's validation.
        cfg.ensure_known_keys("shard", &["count"]).unwrap();
        // A valid section passes, and absent sections are trivially fine.
        cfg.ensure_known_keys("engine", &["overlap_refres", "threads"]).unwrap();
        cfg.ensure_known_keys("train", &["steps"]).unwrap();
    }

    #[test]
    fn engine_section_round_trips() {
        let cfg = Config::parse(
            "[engine]\nthreads = 4\nblock_size = 1024\nrefresh_interval = 10\nstagger_refresh = true\noverlap_refresh = true\npool_threads = 8",
        )
        .unwrap();
        assert_eq!(cfg.usize_or("engine.threads", 0), 4);
        assert_eq!(cfg.usize_or("engine.block_size", 0), 1024);
        assert_eq!(cfg.usize_or("engine.refresh_interval", 1), 10);
        assert!(cfg.bool_or("engine.stagger_refresh", false));
        assert!(cfg.bool_or("engine.overlap_refresh", false));
        assert_eq!(cfg.usize_or("engine.pool_threads", 0), 8);
        // Defaults apply when the keys are absent.
        let empty = Config::default();
        assert!(!empty.bool_or("engine.overlap_refresh", false));
        assert_eq!(empty.usize_or("engine.pool_threads", 0), 0);
    }
}
