//! Bench regression gate (`sketchy bench-gate`).
//!
//! CI runs the quick-mode engine benchmark, which writes
//! `bench_out/BENCH_precond_engine.json`, and compares it against the
//! committed `bench_out/BENCH_baseline.json`: the gate **fails the PR**
//! when any timing metric regresses more than the tolerance (default
//! 25%), or when the bench's bitwise-identity invariant went false.
//!
//! Raw nanosecond medians are not comparable across machines, so the
//! bench also records `calibration_ns` — the median of a fixed
//! *single-threaded* 256×256 matmul measured in the same process. When
//! both records carry a calibration, every `*_ns` metric is compared as
//! a ratio to its own run's calibration, which cancels machine speed to
//! first order and makes a committed baseline meaningful on CI runners
//! of unknown speed. Refresh the baseline by copying the uploaded
//! `BENCH_precond_engine.json` artifact over `BENCH_baseline.json`.
//!
//! Besides regression budgets, the baseline can demand **floors**: a
//! baseline key `<metric>_min` requires the current record to carry
//! `<metric>` with a value at or above the floor. This is how the
//! RefreshAhead overlap win is enforced — `overlap_speedup_min` fails
//! the PR if the pipelined engine stops beating the synchronous one
//! (speedups are already machine-normalized ratios, so no calibration
//! is applied to floors). Symmetrically, `<metric>_max` demands a
//! **ceiling**: the current record must carry `<metric>` at or below
//! the bound. This is how the elastic-fleet handoff is enforced —
//! `shard_migrate_steps_max` fails the PR if a kill-and-replace
//! migration starts replaying more than one failover budget's worth of
//! journal (ceilings are deterministic counters, so no calibration is
//! applied there either).

use super::json::Json;
use anyhow::{bail, Context};

/// Outcome of one gate evaluation.
#[derive(Debug)]
pub struct GateReport {
    /// One line per checked metric (for the CI log).
    pub lines: Vec<String>,
    /// Human-readable reasons the gate fired (empty = pass).
    pub failures: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// Render the full report (checked metrics, then verdict).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        if self.passed() {
            out.push_str("bench-gate: PASS\n");
        } else {
            for f in &self.failures {
                out.push_str("bench-gate FAILURE: ");
                out.push_str(f);
                out.push('\n');
            }
        }
        out
    }
}

/// Read `key` as a gate number. An absent key or a non-number value is
/// `Ok(None)` — the caller decides whether that is a failure. A number
/// that is NaN/±Infinity, or IEEE negative zero, is a named error:
/// `NaN > x` is false for every `x`, so a poisoned record would
/// otherwise sail through every budget/floor/ceiling comparison, and a
/// negative-zero baseline flips ratio signs.
fn gate_num(j: &Json, key: &str, who: &str) -> anyhow::Result<Option<f64>> {
    let Some(v) = j.get(key) else { return Ok(None) };
    let Some(x) = v.as_finite_f64() else {
        if matches!(v, Json::Num(_)) {
            bail!("{who} metric {key} is not finite (NaN or Infinity); refusing to compare");
        }
        return Ok(None);
    };
    if x == 0.0 && x.is_sign_negative() {
        bail!("{who} metric {key} is negative zero; refusing to compare");
    }
    Ok(Some(x))
}

/// [`gate_num`], additionally requiring strict positivity (timings and
/// floor metrics; zero/negative are treated as absent, as before).
fn positive_num(j: &Json, key: &str, who: &str) -> anyhow::Result<Option<f64>> {
    Ok(gate_num(j, key, who)?.filter(|&x| x > 0.0))
}

/// Compare a fresh bench record against the committed baseline.
///
/// Every `*_ns` metric present in the baseline must be present in the
/// current record and must not exceed the baseline by more than
/// `tolerance` (relative). Metrics are normalized by each record's own
/// `calibration_ns` when both carry one. A boolean `identical` field in
/// the current record must be `true` — the benchmark's serial-vs-
/// parallel bitwise check is part of the gate.
///
/// A NaN/±Infinity or negative-zero value on any compared metric — in
/// either record — is a named `Err`, never a silent pass: NaN fails
/// every ordered comparison, so a poisoned record would otherwise
/// clear every budget, floor, and ceiling.
pub fn compare_bench(
    baseline: &Json,
    current: &Json,
    tolerance: f64,
) -> anyhow::Result<GateReport> {
    let base_obj = baseline
        .as_obj()
        .context("baseline record is not a JSON object")?;
    if current.as_obj().is_none() {
        bail!("current record is not a JSON object");
    }
    let mut report = GateReport { lines: vec![], failures: vec![] };
    let base_cal = positive_num(baseline, "calibration_ns", "baseline")?;
    let cur_cal = positive_num(current, "calibration_ns", "current")?;
    let normalized = base_cal.is_some() && cur_cal.is_some();
    if normalized {
        report.lines.push(format!(
            "calibration: baseline {}ns, current {}ns (metrics compared as ratios)",
            base_cal.unwrap(),
            cur_cal.unwrap()
        ));
    } else {
        report.lines.push(
            "calibration: absent in baseline or current — comparing raw nanoseconds".into(),
        );
        // Like a dropped `identical` field, a silently dropped
        // calibration is itself a gate failure: without it the ratios
        // degrade to machine-dependent raw nanoseconds.
        if base_cal.is_some() && cur_cal.is_none() {
            report.failures.push("current record dropped calibration_ns (raw-ns fallback)".into());
        }
    }
    for key in base_obj.keys() {
        if !key.ends_with("_ns") || key.as_str() == "calibration_ns" {
            continue;
        }
        let base_raw = match positive_num(baseline, key, "baseline")? {
            Some(v) => v,
            None => continue,
        };
        let cur_raw = match positive_num(current, key, "current")? {
            Some(v) => v,
            None => {
                report.failures.push(format!("metric {key} missing in current record"));
                continue;
            }
        };
        let (base_v, cur_v) = if normalized {
            (base_raw / base_cal.unwrap(), cur_raw / cur_cal.unwrap())
        } else {
            (base_raw, cur_raw)
        };
        let ratio = cur_v / base_v;
        report.lines.push(format!(
            "{key}: baseline {base_v:.4}, current {cur_v:.4} (x{ratio:.3}, budget x{:.3})",
            1.0 + tolerance
        ));
        if ratio > 1.0 + tolerance {
            report.failures.push(format!(
                "{key} regressed x{ratio:.3} (> x{:.3} budget)",
                1.0 + tolerance
            ));
        }
    }
    // Floor metrics: `<metric>_min` in the baseline demands the current
    // record carry `<metric>` at or above the floor.
    for key in base_obj.keys() {
        let Some(metric) = key.strip_suffix("_min") else {
            continue;
        };
        let floor = match gate_num(baseline, key, "baseline")? {
            Some(v) => v,
            None => continue,
        };
        match positive_num(current, metric, "current")? {
            None => {
                report.failures.push(format!("floor metric {metric} missing in current record"));
            }
            Some(v) => {
                report.lines.push(format!("{metric}: current {v:.4} (floor {floor:.4})"));
                if v < floor {
                    report.failures.push(format!("{metric} {v:.4} under floor {floor:.4}"));
                }
            }
        }
    }
    // Ceiling metrics: `<metric>_max` in the baseline demands the
    // current record carry `<metric>` at or below the bound. Zero is a
    // legitimate ceiling-metric value (e.g. a handoff that replayed no
    // journal), so unlike floors this reads the plain number.
    for key in base_obj.keys() {
        let Some(metric) = key.strip_suffix("_max") else {
            continue;
        };
        let ceiling = match gate_num(baseline, key, "baseline")? {
            Some(v) => v,
            None => continue,
        };
        match gate_num(current, metric, "current")? {
            None => {
                report.failures.push(format!("ceiling metric {metric} missing in current record"));
            }
            Some(v) => {
                report.lines.push(format!("{metric}: current {v:.4} (ceiling {ceiling:.4})"));
                if v > ceiling {
                    report.failures.push(format!("{metric} {v:.4} over ceiling {ceiling:.4}"));
                }
            }
        }
    }
    match current.get("identical") {
        Some(Json::Bool(true)) => report.lines.push("identical: true".into()),
        Some(Json::Bool(false)) => {
            report.failures.push("bench reports identical=false (parallel diverged)".into());
        }
        _ => {
            if matches!(baseline.get("identical"), Some(Json::Bool(_))) {
                report.failures.push("current record lost the 'identical' invariant field".into());
            }
        }
    }
    Ok(report)
}

/// File-reading wrapper for the `bench-gate` CLI.
pub fn run_gate(
    baseline_path: &str,
    current_path: &str,
    tolerance: f64,
) -> anyhow::Result<GateReport> {
    let base_text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("read baseline {baseline_path}"))?;
    let cur_text = std::fs::read_to_string(current_path)
        .with_context(|| format!("read current record {current_path}"))?;
    let baseline = Json::parse(&base_text)
        .map_err(|e| anyhow::anyhow!("parse baseline {baseline_path}: {e}"))?;
    let current = Json::parse(&cur_text)
        .map_err(|e| anyhow::anyhow!("parse current record {current_path}: {e}"))?;
    compare_bench(&baseline, &current, tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(serial: f64, parallel: f64, cal: f64, identical: bool) -> Json {
        Json::parse(&format!(
            r#"{{"serial_median_ns": {serial}, "parallel_median_ns": {parallel},
                 "calibration_ns": {cal}, "identical": {identical}, "blocks": 24}}"#
        ))
        .unwrap()
    }

    #[test]
    fn equal_records_pass() {
        let base = record(1000.0, 400.0, 100.0, true);
        let r = compare_bench(&base, &base, 0.25).unwrap();
        assert!(r.passed(), "failures: {:?}", r.failures);
        assert!(r.render().contains("PASS"));
    }

    #[test]
    fn gate_fires_on_artificially_slowed_run() {
        // The "demonstrably fires" check: a 30% slowdown on one metric
        // must fail a 25% budget.
        let base = record(1000.0, 400.0, 100.0, true);
        let slowed = record(1300.0, 400.0, 100.0, true);
        let r = compare_bench(&base, &slowed, 0.25).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures.len(), 1);
        assert!(r.failures[0].contains("serial_median_ns"), "{:?}", r.failures);
        assert!(r.render().contains("FAILURE"));
    }

    #[test]
    fn slowdown_within_budget_passes() {
        let base = record(1000.0, 400.0, 100.0, true);
        let slower = record(1200.0, 480.0, 100.0, true);
        assert!(compare_bench(&base, &slower, 0.25).unwrap().passed());
        // ...and the same run fails a tighter budget.
        assert!(!compare_bench(&base, &slower, 0.1).unwrap().passed());
    }

    #[test]
    fn calibration_cancels_machine_speed() {
        // A machine 3x slower across the board (calibration included)
        // is not a regression.
        let base = record(1000.0, 400.0, 100.0, true);
        let slow_machine = record(3000.0, 1200.0, 300.0, true);
        let r = compare_bench(&base, &slow_machine, 0.25).unwrap();
        assert!(r.passed(), "failures: {:?}", r.failures);
        // Without calibration the same record would (correctly) fire.
        let base_nocal = Json::parse(r#"{"serial_median_ns": 1000, "identical": true}"#).unwrap();
        let cur_nocal = Json::parse(r#"{"serial_median_ns": 3000, "identical": true}"#).unwrap();
        assert!(!compare_bench(&base_nocal, &cur_nocal, 0.25).unwrap().passed());
    }

    #[test]
    fn genuine_regression_fires_despite_calibration() {
        // Same machine speed (same calibration), engine 2x slower.
        let base = record(1000.0, 400.0, 100.0, true);
        let regressed = record(2000.0, 800.0, 100.0, true);
        let r = compare_bench(&base, &regressed, 0.25).unwrap();
        assert_eq!(r.failures.len(), 2, "{:?}", r.failures);
    }

    #[test]
    fn broken_identity_fires() {
        let base = record(1000.0, 400.0, 100.0, true);
        let diverged = record(1000.0, 400.0, 100.0, false);
        let r = compare_bench(&base, &diverged, 0.25).unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("identical"), "{:?}", r.failures);
        // Dropping the field entirely (while the baseline tracks it)
        // also fires — a silently deleted invariant is not a pass.
        let missing = Json::parse(
            r#"{"serial_median_ns": 1000, "parallel_median_ns": 400, "calibration_ns": 100}"#,
        )
        .unwrap();
        assert!(!compare_bench(&base, &missing, 0.25).unwrap().passed());
    }

    #[test]
    fn missing_metric_fires_and_faster_passes() {
        let base = record(1000.0, 400.0, 100.0, true);
        let missing = Json::parse(r#"{"calibration_ns": 100, "identical": true}"#).unwrap();
        let r = compare_bench(&base, &missing, 0.25).unwrap();
        assert_eq!(r.failures.len(), 2, "{:?}", r.failures);
        // Improvements are never failures.
        let faster = record(500.0, 200.0, 100.0, true);
        assert!(compare_bench(&base, &faster, 0.25).unwrap().passed());
    }

    #[test]
    fn lost_calibration_fires() {
        let base = record(1000.0, 400.0, 100.0, true);
        let cur = Json::parse(
            r#"{"serial_median_ns": 1000, "parallel_median_ns": 400, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &cur, 0.25).unwrap();
        assert!(!r.passed());
        assert!(
            r.failures.iter().any(|f| f.contains("calibration")),
            "{:?}",
            r.failures
        );
        // Baselines without calibration stay on raw-ns comparison
        // without firing this rule (covered elsewhere).
    }

    #[test]
    fn floor_metric_enforced() {
        let base = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "overlap_speedup_min": 1.2, "identical": true}"#,
        )
        .unwrap();
        // At/above the floor passes.
        let good = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "overlap_speedup": 1.45, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &good, 0.25).unwrap();
        assert!(r.passed(), "failures: {:?}", r.failures);
        assert!(r.render().contains("floor"));
        // Below the floor fires.
        let slow = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "overlap_speedup": 1.05, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &slow, 0.25).unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("overlap_speedup"), "{:?}", r.failures);
        // Dropping the metric entirely also fires.
        let missing = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &missing, 0.25).unwrap();
        assert!(!r.passed());
        assert!(
            r.failures.iter().any(|f| f.contains("missing")),
            "{:?}",
            r.failures
        );
    }

    #[test]
    fn ceiling_metric_enforced() {
        let base = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "shard_migrate_steps_max": 8, "identical": true}"#,
        )
        .unwrap();
        // At/below the ceiling passes — including zero, which the
        // positive-number floor path would have treated as missing.
        for steps in ["0", "2", "8"] {
            let good = Json::parse(&format!(
                r#"{{"serial_median_ns": 1000, "calibration_ns": 100,
                     "shard_migrate_steps": {steps}, "identical": true}}"#
            ))
            .unwrap();
            let r = compare_bench(&base, &good, 0.25).unwrap();
            assert!(r.passed(), "steps {steps}: failures: {:?}", r.failures);
            assert!(r.render().contains("ceiling"));
        }
        // Over the ceiling fires.
        let over = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "shard_migrate_steps": 9, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &over, 0.25).unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("over ceiling"), "{:?}", r.failures);
        // Dropping the metric entirely also fires.
        let missing = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100, "identical": true}"#,
        )
        .unwrap();
        let r = compare_bench(&base, &missing, 0.25).unwrap();
        assert!(!r.passed());
        assert!(
            r.failures.iter().any(|f| f.contains("ceiling metric shard_migrate_steps missing")),
            "{:?}",
            r.failures
        );
    }

    #[test]
    fn non_object_records_error() {
        let base = record(1000.0, 400.0, 100.0, true);
        assert!(compare_bench(&Json::parse("[1,2]").unwrap(), &base, 0.25).is_err());
        assert!(compare_bench(&base, &Json::parse("3").unwrap(), 0.25).is_err());
    }

    #[test]
    fn nan_baseline_metric_is_a_named_error() {
        // A NaN baseline previously decayed to "metric absent": the
        // whole budget comparison was silently skipped.
        let base = Json::parse(
            r#"{"serial_median_ns": NaN, "calibration_ns": 100, "identical": true}"#,
        )
        .unwrap();
        let cur = record(1000.0, 400.0, 100.0, true);
        let err = compare_bench(&base, &cur, 0.25).unwrap_err().to_string();
        assert!(err.contains("serial_median_ns"), "{err}");
        assert!(err.contains("not finite"), "{err}");
        assert!(err.contains("baseline"), "{err}");
    }

    #[test]
    fn nan_current_ceiling_value_is_a_named_error() {
        // The worst of the old bugs: `NaN > ceiling` is false, so a
        // poisoned current record sailed under every ceiling.
        let base = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "shard_migrate_steps_max": 8, "identical": true}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "shard_migrate_steps": NaN, "identical": true}"#,
        )
        .unwrap();
        let err = compare_bench(&base, &cur, 0.25).unwrap_err().to_string();
        assert!(err.contains("shard_migrate_steps"), "{err}");
        assert!(err.contains("current"), "{err}");
    }

    #[test]
    fn negative_zero_baseline_is_a_named_error() {
        let base = Json::parse(
            r#"{"serial_median_ns": -0.0, "calibration_ns": 100, "identical": true}"#,
        )
        .unwrap();
        let cur = record(1000.0, 400.0, 100.0, true);
        let err = compare_bench(&base, &cur, 0.25).unwrap_err().to_string();
        assert!(err.contains("negative zero"), "{err}");
    }

    #[test]
    fn infinite_floor_bound_is_a_named_error() {
        let base = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "overlap_speedup_min": -Infinity, "identical": true}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"serial_median_ns": 1000, "calibration_ns": 100,
                 "overlap_speedup": 1.4, "identical": true}"#,
        )
        .unwrap();
        let err = compare_bench(&base, &cur, 0.25).unwrap_err().to_string();
        assert!(err.contains("overlap_speedup_min"), "{err}");
        assert!(err.contains("not finite"), "{err}");
    }
}
