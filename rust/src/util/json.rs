//! Minimal JSON parser/serializer.
//!
//! Used for the AOT artifact manifest and the cross-language numeric
//! fixtures dumped by `python/compile/aot.py` (serde is not vendored).
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number accessor that refuses non-finite values: `None` for NaN
    /// and ±Infinity, which this parser accepts (python emits them for
    /// `float('nan')` etc.) but which poison ordered comparisons —
    /// `NaN > x` is false for every `x`, so a NaN smuggled into a gate
    /// or threshold would silently pass. Callers that compare should
    /// use this and decide loudly what a non-finite number means.
    pub fn as_finite_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) if x.is_finite() => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Array of numbers → Vec<f64>.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect::<Vec<_>>())
            .filter(|v: &Vec<f64>| v.len() == self.as_arr().unwrap().len())
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            // Allow -Infinity (python json emits it for float('-inf')).
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy the full UTF-8 sequence.
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn f64_vec_helper() {
        let j = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(j.to_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        assert!(Json::parse("[1, \"x\"]").unwrap().to_f64_vec().is_none());
    }

    #[test]
    fn python_inf_nan() {
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(
            Json::parse("-Infinity").unwrap().as_f64().unwrap(),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn finite_accessor_refuses_nan_and_infinities() {
        assert_eq!(Json::parse("2.5").unwrap().as_finite_f64(), Some(2.5));
        assert_eq!(Json::parse("-0.0").unwrap().as_finite_f64(), Some(-0.0));
        assert_eq!(Json::parse("NaN").unwrap().as_finite_f64(), None);
        assert_eq!(Json::parse("Infinity").unwrap().as_finite_f64(), None);
        assert_eq!(Json::parse("-Infinity").unwrap().as_finite_f64(), None);
        assert_eq!(Json::parse("\"3\"").unwrap().as_finite_f64(), None);
    }
}
