//! Cross-cutting utilities built from scratch for the offline environment:
//! PCG64 RNG, a JSON parser (fixtures + manifest), a TOML-subset config
//! parser, a CLI argument parser, a bench harness, a bench regression
//! gate (CI), and a tiny property-testing helper.

pub mod bench;
pub mod cli;
pub mod config;
pub mod gate;
pub mod json;
pub mod proptest;
pub mod rng;
