//! Lightweight property-testing helper (proptest is not vendored).
//!
//! `for_all(seed, cases, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; on failure it reruns the generator to find the
//! smallest failing case index and reports the seed so the case is
//! reproducible. Generators are plain closures over [`Pcg64`].

use crate::util::rng::Pcg64;

/// Run `prop` on `cases` values drawn by `gen`; panics with a reproducible
/// seed + case index on the first failure.
pub fn for_all<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed: seed={seed} case={case}\ninput={input:?}"
            );
        }
    }
}

/// Like [`for_all`] but the property returns `Result<(), String>` so
/// failures carry a message.
pub fn for_all_msg<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed: seed={seed} case={case}: {msg}\ninput={input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_on_true_property() {
        for_all(1, 50, |rng| rng.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        for_all(2, 50, |rng| rng.below(100), |&x| x < 50);
    }

    #[test]
    fn msg_variant() {
        for_all_msg(
            3,
            20,
            |rng| rng.uniform(),
            |&x| {
                if (0.0..1.0).contains(&x) {
                    Ok(())
                } else {
                    Err(format!("out of range: {x}"))
                }
            },
        );
    }
}
