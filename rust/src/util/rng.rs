//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we implement PCG64 (the
//! "PCG XSL RR 128/64" member of the PCG family) plus the distribution
//! helpers the experiments need. Every experiment in this repository is
//! seeded, so results in EXPERIMENTS.md are bit-reproducible.

/// PCG64 generator (XSL-RR 128/64).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (the stream id is derived from the seed too).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15) << 1) | 1,
        };
        rng.state = rng.state.wrapping_add(seed as u128).wrapping_mul(PCG_MULT);
        // Burn a few outputs so trivially-related seeds decorrelate.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-light — the cached-pair variant measured no faster here).
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Vector of iid standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Sample an index from an (unnormalized) non-negative weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli draw with probability p.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Split off an independent child generator (for worker threads).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(5);
        let w = [1.0, 3.0];
        let mut c1 = 0;
        for _ in 0..40_000 {
            if rng.categorical(&w) == 1 {
                c1 += 1;
            }
        }
        let frac = c1 as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg64::new(123);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
