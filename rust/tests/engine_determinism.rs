//! Integration tests for the parallel blocked preconditioner engine.
//!
//! The engine's contract: per-block work is self-contained, so thread
//! count is *never* allowed to change the numbers — the parallel path
//! must produce bitwise-identical parameters to the serial path — and
//! driving the shared `Preconditioner` units through the engine must
//! reproduce the reference optimizers they were extracted from.

use sketchy::optim::{
    Adam, EngineConfig, GraftType, Optimizer, PrecondEngine, Shampoo, ShampooConfig,
};
use sketchy::tensor::{at_a, Matrix};
use sketchy::util::proptest::for_all_msg;
use sketchy::util::rng::Pcg64;

fn base_cfg() -> ShampooConfig {
    ShampooConfig {
        lr: 0.05,
        start_preconditioning_step: 2,
        graft: GraftType::Rmsprop,
        clip: 5.0,
        weight_decay: 1e-3,
        ..Default::default()
    }
}

fn random_grads(shapes: &[(usize, usize)], rng: &mut Pcg64) -> Vec<Matrix> {
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, rng)).collect()
}

/// Step two engines (serial vs parallel) on an identical gradient stream
/// and assert bitwise-equal parameters after every step.
fn assert_parallel_matches_serial(
    shapes: &[(usize, usize)],
    make: impl Fn(EngineConfig) -> PrecondEngine,
    block_size: usize,
    steps: usize,
    seed: u64,
) {
    let serial_cfg = EngineConfig {
        threads: 1,
        block_size,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let parallel_cfg = EngineConfig { threads: 4, ..serial_cfg };
    let mut serial = make(serial_cfg);
    let mut parallel = make(parallel_cfg);
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(seed);
    for step in 0..steps {
        let grads = random_grads(shapes, &mut rng);
        serial.step(&mut p1, &grads);
        parallel.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(
                a.max_diff(b),
                0.0,
                "parallel diverged from serial at step {step}"
            );
        }
    }
}

#[test]
fn parallel_shampoo_engine_bitwise_matches_serial() {
    let shapes = [(10, 7), (6, 6), (9, 1)];
    assert_parallel_matches_serial(
        &shapes,
        |ecfg| PrecondEngine::shampoo(&shapes, base_cfg(), ecfg),
        4,
        15,
        310,
    );
}

#[test]
fn parallel_sketched_engine_bitwise_matches_serial() {
    let shapes = [(12, 10), (8, 3)];
    assert_parallel_matches_serial(
        &shapes,
        |ecfg| PrecondEngine::sketched(&shapes, 3, base_cfg(), ecfg),
        5,
        15,
        311,
    );
}

#[test]
fn engine_reproduces_plain_shampoo_bitwise() {
    // Unblocked engine with the Shampoo cadence (stagger off,
    // refresh_interval = precond_interval) must equal the reference
    // Shampoo step for step: the refactor onto Preconditioner units and
    // the engine driver changed no math.
    let shapes = [(7, 5), (4, 4), (6, 1)];
    let base = ShampooConfig {
        stat_interval: 2,
        precond_interval: 3,
        start_preconditioning_step: 3,
        graft: GraftType::RmspropNormalized,
        ..base_cfg()
    };
    let ecfg = EngineConfig {
        threads: 3,
        block_size: 0,
        refresh_interval: base.precond_interval,
        stagger: false,
        ..Default::default()
    };
    let mut reference = Shampoo::new(&shapes, base.clone());
    let mut engine = PrecondEngine::shampoo(&shapes, base, ecfg);
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(312);
    for step in 0..20 {
        let grads = random_grads(&shapes, &mut rng);
        reference.step(&mut p1, &grads);
        engine.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "engine diverged from Shampoo at step {step}");
        }
    }
}

#[test]
fn blocked_engine_adam_equals_fused_adam() {
    // Adam is elementwise, so the blocked engine path must reproduce the
    // fused implementation bitwise even across an arbitrary partition.
    // The base config deliberately carries Shampoo-flavoured settings
    // (grafting, driver momentum, intervals): PrecondEngine normalizes
    // them away for UnitKind::Adam, so `engine-adam` can never silently
    // stack a second momentum or graft on top of AdamUnit.
    let shapes = [(5, 4), (3, 3)];
    let mut fused = Adam::new(&shapes, 0.05);
    fused.weight_decay = 0.01;
    fused.clip = 1.0;
    let base = ShampooConfig {
        lr: 0.05,
        beta2: 0.999,
        weight_decay: 0.01,
        clip: 1.0,
        // Everything below is normalized away by the Adam engine path.
        beta1: 0.9,
        start_preconditioning_step: 7,
        stat_interval: 2,
        precond_interval: 3,
        graft: GraftType::RmspropNormalized,
        ..Default::default()
    };
    let ecfg = EngineConfig {
        threads: 3,
        block_size: 2,
        refresh_interval: 1,
        stagger: false,
        ..Default::default()
    };
    let mut engine = PrecondEngine::adam(&shapes, base, ecfg);
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(313);
    for step in 0..25 {
        let grads = random_grads(&shapes, &mut rng);
        fused.step(&mut p1, &grads);
        engine.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "engine Adam diverged at step {step}");
        }
    }
}

#[test]
fn ekfac_parallel_shampoo_engine_bitwise_matches_serial() {
    // The EKFAC corrector mutates per-unit state every preconditioned
    // step; thread count must still never change the numbers.
    let shapes = [(10, 7), (6, 6), (9, 1)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    assert_parallel_matches_serial(
        &shapes,
        |ecfg| PrecondEngine::shampoo(&shapes, base.clone(), EngineConfig { ekfac: true, ..ecfg }),
        4,
        15,
        316,
    );
}

#[test]
fn ekfac_parallel_sketched_engine_bitwise_matches_serial() {
    let shapes = [(12, 10), (8, 3)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    assert_parallel_matches_serial(
        &shapes,
        |ecfg| {
            PrecondEngine::sketched(&shapes, 3, base.clone(), EngineConfig { ekfac: true, ..ecfg })
        },
        5,
        15,
        317,
    );
}

#[test]
fn ekfac_engine_reproduces_fused_shampoo_bitwise() {
    // The corrector's track() sits between refresh and apply in both
    // the fused step and the engine's drive_block; under the matched
    // cadence (stagger off, refresh_interval = precond_interval) the
    // two paths must stay bitwise identical with ekfac on.
    let shapes = [(7, 5), (4, 4), (6, 1)];
    let base = ShampooConfig {
        stat_interval: 2,
        precond_interval: 3,
        start_preconditioning_step: 3,
        graft: GraftType::RmspropNormalized,
        ekfac: true,
        ..base_cfg()
    };
    let ecfg = EngineConfig {
        threads: 3,
        block_size: 0,
        refresh_interval: base.precond_interval,
        stagger: false,
        ekfac: true,
        ..Default::default()
    };
    let mut reference = Shampoo::new(&shapes, base.clone());
    let mut engine = PrecondEngine::shampoo(&shapes, base, ecfg);
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(318);
    for step in 0..20 {
        let grads = random_grads(&shapes, &mut rng);
        reference.step(&mut p1, &grads);
        engine.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(
                a.max_diff(b),
                0.0,
                "ekfac engine diverged from fused Shampoo at step {step}"
            );
        }
    }
}

#[test]
fn ekfac_overlap_refresh_bitwise_matches_sync() {
    // RefreshAhead prefetches eigendecompositions, never corrector
    // mutations (the due-set excludes stat steps), so overlap must stay
    // bitwise identical to the synchronous schedule with ekfac on —
    // for exact-Kronecker and FD-sketched units both.
    let shapes = [(10, 8), (6, 6), (7, 1)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    for sketched in [false, true] {
        let make = |overlap: bool| {
            let ecfg = EngineConfig {
                threads: 3,
                block_size: 4,
                refresh_interval: 4,
                stagger: true,
                overlap,
                ekfac: true,
                ..Default::default()
            };
            if sketched {
                PrecondEngine::sketched(&shapes, 3, base.clone(), ecfg)
            } else {
                PrecondEngine::shampoo(&shapes, base.clone(), ecfg)
            }
        };
        let mut sync = make(false);
        let mut over = make(true);
        let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(319);
        for step in 0..18 {
            let grads = random_grads(&shapes, &mut rng);
            sync.step(&mut p1, &grads);
            over.step(&mut p2, &grads);
            for (a, b) in p1.iter().zip(&p2) {
                assert_eq!(
                    a.max_diff(b),
                    0.0,
                    "overlap diverged from sync at step {step} (sketched={sketched})"
                );
            }
        }
        assert!(over.refreshes() > 0);
    }
}

#[test]
fn ekfac_state_snapshot_restore_is_bitwise() {
    // Corrector diagonals/tails ride the typed snapshot payloads: a
    // fresh engine restored from a mid-run snapshot must continue
    // bitwise identically to the uninterrupted one — the invariant the
    // checkpoint-v2 and journal-resume paths both lean on.
    let shapes = [(9, 6), (5, 5), (8, 1)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    for sketched in [false, true] {
        let make = || {
            let ecfg = EngineConfig {
                threads: 2,
                block_size: 4,
                refresh_interval: 3,
                stagger: true,
                ekfac: true,
                ..Default::default()
            };
            if sketched {
                PrecondEngine::sketched(&shapes, 3, base.clone(), ecfg)
            } else {
                PrecondEngine::shampoo(&shapes, base.clone(), ecfg)
            }
        };
        let mut original = make();
        let mut params: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut rng = Pcg64::new(320);
        for _ in 0..9 {
            let grads = random_grads(&shapes, &mut rng);
            original.step(&mut params, &grads);
        }
        let snap = original
            .state_payloads()
            .unwrap()
            .expect("engine must expose typed state");
        let mut restored = make();
        restored.restore_payloads(9, snap).unwrap();
        let mut p1 = params.clone();
        let mut p2 = params;
        for step in 0..9 {
            let grads = random_grads(&shapes, &mut rng);
            original.step(&mut p1, &grads);
            restored.step(&mut p2, &grads);
            for (a, b) in p1.iter().zip(&p2) {
                assert_eq!(
                    a.max_diff(b),
                    0.0,
                    "restored engine diverged at step {step} (sketched={sketched})"
                );
            }
        }
    }
}

#[test]
fn fd_invariants_survive_concurrent_block_updates() {
    // Property test over random shapes/seeds: after parallel stepping, every
    // per-block FD sketch still satisfies the Alg. 1 invariants — the ℓ-th
    // eigenvalue is exactly zero (deflation ran), eigenvalues descend, and
    // the active basis is orthonormal.
    for_all_msg(
        314,
        8,
        |rng| {
            let m = 8 + rng.below(7);
            let n = 8 + rng.below(7);
            let rank = 3 + rng.below(2);
            let seed = rng.below(1 << 20) as u64;
            (m, n, rank, seed)
        },
        |&(m, n, rank, seed)| {
            let shapes = [(m, n)];
            let base = ShampooConfig {
                lr: 0.03,
                start_preconditioning_step: 2,
                graft: GraftType::Rmsprop,
                ..Default::default()
            };
            let ecfg = EngineConfig {
                threads: 4,
                block_size: 6,
                refresh_interval: 2,
                stagger: true,
                ..Default::default()
            };
            let mut engine = PrecondEngine::sketched(&shapes, rank, base, ecfg);
            let mut params = vec![Matrix::zeros(m, n)];
            let mut rng = Pcg64::new(seed);
            for _ in 0..10 {
                let grads = random_grads(&shapes, &mut rng);
                engine.step(&mut params, &grads);
            }
            let mut checked = 0usize;
            let mut failure = None;
            engine.for_each_sketch(|fd| {
                checked += 1;
                let w = fd.eigenvalues();
                let ell = fd.rank();
                if w[ell - 1] != 0.0 {
                    failure = Some(format!("ell-th eigenvalue nonzero: {}", w[ell - 1]));
                    return;
                }
                for i in 1..w.len() {
                    if w[i - 1] < w[i] - 1e-12 {
                        failure = Some(format!("eigenvalues not descending at {i}"));
                        return;
                    }
                }
                let k = fd.active_rank();
                if k > 0 {
                    let basis = fd.basis().slice(0, fd.dim(), 0, k);
                    let gram = at_a(&basis);
                    let err = gram.max_diff(&Matrix::eye(k));
                    if err > 1e-8 {
                        failure = Some(format!("basis not orthonormal: {err}"));
                    }
                }
            });
            if let Some(msg) = failure {
                return Err(msg);
            }
            if checked == 0 {
                return Err("no sketched sides found — shrink rank or grow blocks".into());
            }
            Ok(())
        },
    );
}

#[test]
fn stale_refresh_schedule_amortizes_eigendecompositions() {
    // refresh_interval = 4 with staggering: each block refreshes its
    // inverse roots once per 4 steps (plus a forced first-use refresh),
    // i.e. ~4x fewer eigendecompositions than the always-fresh schedule,
    // spread across steps instead of bunched.
    let shapes = [(8, 8)];
    let base = ShampooConfig {
        lr: 0.05,
        start_preconditioning_step: 1,
        graft: GraftType::Rmsprop,
        ..Default::default()
    };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4, // 4 blocks
        refresh_interval: 4,
        stagger: true,
        ..Default::default()
    };
    let mut engine = PrecondEngine::shampoo(&shapes, base, ecfg);
    assert_eq!(engine.blocks().len(), 4);
    let mut params = vec![Matrix::zeros(8, 8)];
    let mut rng = Pcg64::new(315);
    let steps = 16;
    for _ in 0..steps {
        let grads = random_grads(&shapes, &mut rng);
        engine.step(&mut params, &grads);
    }
    let blocks = engine.blocks().len();
    let scheduled = steps * blocks / 4;
    assert!(
        engine.refreshes() >= scheduled && engine.refreshes() <= scheduled + blocks,
        "refreshes {} outside amortized range [{}, {}]",
        engine.refreshes(),
        scheduled,
        scheduled + blocks
    );
    // Sanity: the amortized engine still made parameter progress.
    assert!(params[0].fro_norm() > 0.0);
}
