//! Integration tests over the experiment harness: every experiment runs
//! end-to-end at reduced scale and its paper-shape claims hold.
//! Artifact-dependent tests skip gracefully when `make artifacts` hasn't
//! run.

use sketchy::util::cli::Args;

fn args(pairs: &[(&str, &str)]) -> Args {
    let mut a = Args::default();
    for (k, v) in pairs {
        a.options.insert(k.to_string(), v.to_string());
    }
    a
}

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: run `make artifacts` first");
    }
    ok
}

#[test]
fn fig1_memory_table() {
    let report = sketchy::experiments::fig1::run(&Args::default()).unwrap();
    assert!(report.contains("Sketchy"));
    assert!(report.contains("140.74 TB")); // (mn)² at 4096x1024, f64
}

#[test]
fn tbl1_bounds_hold_at_reduced_scale() {
    let report =
        sketchy::experiments::tbl1::run(&args(&[("d", "24"), ("t", "400")])).unwrap();
    assert!(!report.contains("| NO |"), "bound violated:\n{report}");
}

#[test]
fn appg_step_skipping_cheap() {
    let report = sketchy::experiments::appg::run(&args(&[
        ("d", "8"),
        ("t", "800"),
        ("seeds", "2"),
    ]))
    .unwrap();
    assert!(report.contains("far below"));
}

#[test]
fn fig2_single_task_ordering() {
    if !have_artifacts() {
        return;
    }
    let report = sketchy::experiments::fig2::run(&args(&[
        ("task", "graph"),
        ("steps", "40"),
        ("workers", "1"),
    ]))
    .unwrap();
    assert!(report.contains("S-Shampoo"));
    assert!(report.contains("covariance bytes"));
}

#[test]
fn fig2_engine_cell_with_ekfac() {
    if !have_artifacts() {
        return;
    }
    // An engine-* cell runs the bitwise engine ≡ fused pre-flight
    // before recording; with --ekfac the corrector is live on a
    // stretched refresh cadence.
    let report = sketchy::experiments::fig2::run(&args(&[
        ("task", "graph"),
        ("steps", "40"),
        ("workers", "1"),
        ("optimizer", "engine-s-shampoo"),
        ("ekfac", "true"),
        ("refresh-interval", "8"),
    ]))
    .unwrap();
    assert!(report.contains("engine-s-shampoo"), "{report}");
    assert!(report.contains("ekfac"), "{report}");
}

#[test]
fn fig3_spectra_collected() {
    if !have_artifacts() {
        return;
    }
    let report = sketchy::experiments::fig3::run(&args(&[
        ("task", "graph"),
        ("steps", "30"),
        ("workers", "1"),
    ]))
    .unwrap();
    assert!(report.contains("intrinsic dim"));
    assert!(report.contains("Wishart"));
}

#[test]
fn e2e_lm_s_shampoo_loss_decreases() {
    if !have_artifacts() {
        return;
    }
    use sketchy::data::MarkovCorpus;
    use sketchy::optim::{GraftType, SShampoo, SShampooConfig, ShampooConfig};
    use sketchy::train::LmTrainer;
    use std::sync::Arc;
    let rt = Arc::new(sketchy::runtime::Runtime::load("artifacts").unwrap());
    let mut trainer = LmTrainer::new(rt, "tiny", 5).unwrap();
    let shapes = trainer.shapes.clone();
    let mut opt = SShampoo::new(
        &shapes,
        SShampooConfig {
            base: ShampooConfig {
                lr: 5e-3,
                start_preconditioning_step: 5,
                graft: GraftType::RmspropNormalized,
                clip: 10.0,
                ..Default::default()
            },
            rank: 8,
        },
    );
    let mut corpus = MarkovCorpus::new(trainer.vocab, 2);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (loss, _) = trainer.step(&mut opt, &mut corpus, 2).unwrap();
        first.get_or_insert(loss);
        last = loss;
    }
    assert!(
        last < first.unwrap() - 0.1,
        "S-Shampoo LM loss did not decrease: {first:?} -> {last}"
    );
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    if !have_artifacts() {
        return;
    }
    use sketchy::train::{load_checkpoint, save_checkpoint, LmTrainer};
    use std::sync::Arc;
    let rt = Arc::new(sketchy::runtime::Runtime::load("artifacts").unwrap());
    let trainer = LmTrainer::new(rt, "tiny", 5).unwrap();
    let path = std::env::temp_dir().join("sketchy_e2e_ckpt.bin");
    save_checkpoint(path.to_str().unwrap(), 7, &trainer.params).unwrap();
    let (step, params) = load_checkpoint(path.to_str().unwrap()).unwrap();
    assert_eq!(step, 7);
    assert_eq!(params.len(), trainer.params.len());
    for (a, b) in params.iter().zip(&trainer.params) {
        assert_eq!(a, b);
    }
    std::fs::remove_file(path).ok();
}
