//! Integration tests over the PJRT runtime and the AOT artifacts.
//!
//! These require `make artifacts` to have been run (the Makefile `test`
//! target guarantees it). If the manifest is absent the tests skip with a
//! notice rather than failing, so plain `cargo test` works on a fresh
//! checkout.

use sketchy::runtime::artifact::load_fixture;
use sketchy::runtime::literal::{lit_f32, lit_scalar, lit_to_f64};
use sketchy::runtime::Runtime;
use std::sync::Arc;

const DIR: &str = "artifacts";

fn runtime_or_skip() -> Option<Arc<Runtime>> {
    if !std::path::Path::new(DIR).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
        return None;
    }
    Some(Arc::new(Runtime::load(DIR).expect("runtime load")))
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.names();
    for required in [
        "lm_tiny_grad",
        "lm_tiny_eval",
        "cnn_grad",
        "cnn_eval",
        "conformer_grad",
        "conformer_eval",
        "gnn_grad",
        "gnn_eval",
        "cov_update_64",
        "cov_update_256",
        "precond_apply_128x64",
        "sketch_gram_512",
    ] {
        assert!(names.iter().any(|n| n == required), "missing {required}");
    }
}

#[test]
fn every_artifact_compiles() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in rt.names() {
        rt.executable(&name)
            .unwrap_or_else(|e| panic!("compile {name}: {e}"));
    }
}

#[test]
fn cov_update_fixture_matches_jax() {
    let Some(rt) = runtime_or_skip() else { return };
    let fx = load_fixture(DIR, "cov_update_64").expect("fixture");
    let inputs: Vec<xla::Literal> = fx
        .inputs
        .iter()
        .map(|(_, shape, data)| {
            let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
            lit_f32(&f32s, shape).unwrap()
        })
        .collect();
    let outs = rt.execute("cov_update_64", &inputs).unwrap();
    let got = lit_to_f64(&outs[0]).unwrap();
    let want = &fx.outputs[0];
    assert_eq!(got.len(), want.len());
    let mut max_err = 0.0f64;
    for (g, w) in got.iter().zip(want) {
        max_err = max_err.max((g - w).abs() / (1.0 + w.abs()));
    }
    assert!(max_err < 1e-4, "cov_update mismatch: rel err {max_err}");
}

#[test]
fn cov_update_artifact_matches_rust_reference() {
    // Cross-language: the XLA/Pallas kernel and the Rust tensor substrate
    // must agree on beta2*C + G^T G.
    let Some(rt) = runtime_or_skip() else { return };
    use sketchy::tensor::{at_a, Matrix};
    use sketchy::util::rng::Pcg64;
    let mut rng = Pcg64::new(42);
    let c = Matrix::randn(64, 64, &mut rng);
    let g = Matrix::randn(64, 64, &mut rng);
    let c32: Vec<f32> = c.as_slice().iter().map(|&x| x as f32).collect();
    let g32: Vec<f32> = g.as_slice().iter().map(|&x| x as f32).collect();
    let outs = rt
        .execute(
            "cov_update_64",
            &[lit_f32(&c32, &[64, 64]).unwrap(), lit_f32(&g32, &[64, 64]).unwrap()],
        )
        .unwrap();
    let got = lit_to_f64(&outs[0]).unwrap();
    let mut want = at_a(&g);
    want.axpy(0.0, &c); // shape check only
    let want = c.scale(0.999).add(&at_a(&g));
    let mut max_err = 0.0f64;
    for (i, (g_, w)) in got.iter().zip(want.as_slice()).enumerate() {
        let e = (g_ - w).abs() / (1.0 + w.abs());
        if e > max_err {
            max_err = e;
            let _ = i;
        }
    }
    assert!(max_err < 1e-4, "xla vs rust mismatch: {max_err}");
}

#[test]
fn precond_apply_fixture_matches_jax() {
    let Some(rt) = runtime_or_skip() else { return };
    let fx = load_fixture(DIR, "precond_apply_128x64").expect("fixture");
    let inputs: Vec<xla::Literal> = fx
        .inputs
        .iter()
        .map(|(_, shape, data)| {
            let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
            lit_f32(&f32s, shape).unwrap()
        })
        .collect();
    let outs = rt.execute("precond_apply_128x64", &inputs).unwrap();
    let got = lit_to_f64(&outs[0]).unwrap();
    let want = &fx.outputs[0];
    let mut max_err = 0.0f64;
    for (g, w) in got.iter().zip(want) {
        max_err = max_err.max((g - w).abs() / (1.0 + w.abs()));
    }
    assert!(max_err < 1e-3, "precond_apply mismatch: rel err {max_err}");
}

#[test]
fn lm_tiny_eval_fixture_matches_jax() {
    let Some(rt) = runtime_or_skip() else { return };
    let fx = load_fixture(DIR, "lm_tiny_eval").expect("fixture");
    let spec = rt.spec("lm_tiny_eval").unwrap().clone();
    let inputs: Vec<xla::Literal> = fx
        .inputs
        .iter()
        .zip(&spec.inputs)
        .map(|((_, shape, data), io)| {
            if io.dtype == "i32" {
                let i32s: Vec<i32> = data.iter().map(|&x| x as i32).collect();
                sketchy::runtime::literal::lit_i32(&i32s, shape).unwrap()
            } else {
                let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                lit_f32(&f32s, shape).unwrap()
            }
        })
        .collect();
    let outs = rt.execute("lm_tiny_eval", &inputs).unwrap();
    let loss = lit_scalar(&outs[0]).unwrap();
    let want = fx.outputs[0][0];
    assert!(
        (loss - want).abs() < 1e-4 * (1.0 + want.abs()),
        "loss {loss} vs jax {want}"
    );
}

#[test]
fn lm_tiny_grad_executes_with_sane_outputs() {
    let Some(rt) = runtime_or_skip() else { return };
    use sketchy::train::artifact_worker::init_params_from_specs;
    let spec = rt.spec("lm_tiny_grad").unwrap().clone();
    let (_, shapes, params) = init_params_from_specs(&spec.inputs, spec.n_params, 7);
    let mut inputs: Vec<xla::Literal> = params
        .iter()
        .map(|p| sketchy::runtime::literal::matrix_to_lit(p).unwrap())
        .collect();
    let tok_shape = &spec.inputs[spec.n_params].shape;
    let tokens: Vec<i32> = (0..tok_shape.iter().product::<usize>())
        .map(|i| (i % 31) as i32)
        .collect();
    inputs.push(sketchy::runtime::literal::lit_i32(&tokens, tok_shape).unwrap());
    let outs = rt.execute("lm_tiny_grad", &inputs).unwrap();
    assert_eq!(outs.len(), shapes.len() + 1);
    let loss = lit_scalar(&outs[0]).unwrap();
    // Vocab 32 ⇒ loss near ln 32 ≈ 3.47 at random init.
    assert!(loss > 1.0 && loss < 6.0, "init loss {loss}");
    for (i, &(r, c)) in shapes.iter().enumerate() {
        let g = lit_to_f64(&outs[1 + i]).unwrap();
        assert_eq!(g.len(), r * c);
        assert!(g.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn concurrent_execution_is_safe() {
    // The coordinator executes artifacts from multiple worker threads;
    // verify results stay deterministic under concurrency.
    let Some(rt) = runtime_or_skip() else { return };
    use sketchy::tensor::Matrix;
    use sketchy::util::rng::Pcg64;
    let mut rng = Pcg64::new(9);
    let c = Matrix::randn(64, 64, &mut rng);
    let g = Matrix::randn(64, 64, &mut rng);
    let c32: Vec<f32> = c.as_slice().iter().map(|&x| x as f32).collect();
    let g32: Vec<f32> = g.as_slice().iter().map(|&x| x as f32).collect();
    // Warm the executable cache first.
    rt.executable("cov_update_64").unwrap();
    let reference: Vec<f64> = {
        let outs = rt
            .execute(
                "cov_update_64",
                &[lit_f32(&c32, &[64, 64]).unwrap(), lit_f32(&g32, &[64, 64]).unwrap()],
            )
            .unwrap();
        lit_to_f64(&outs[0]).unwrap()
    };
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let rt = rt.clone();
                let c32 = c32.clone();
                let g32 = g32.clone();
                scope.spawn(move || {
                    let outs = rt
                        .execute(
                            "cov_update_64",
                            &[
                                lit_f32(&c32, &[64, 64]).unwrap(),
                                lit_f32(&g32, &[64, 64]).unwrap(),
                            ],
                        )
                        .unwrap();
                    lit_to_f64(&outs[0]).unwrap()
                })
            })
            .collect();
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, reference, "concurrent result diverged");
        }
    });
}

#[test]
fn lm_training_smoke_loss_decreases() {
    // E2E smoke: 25 steps of Adam on the tiny LM must cut the loss.
    let Some(rt) = runtime_or_skip() else { return };
    use sketchy::data::MarkovCorpus;
    use sketchy::optim::{Adam, Optimizer};
    use sketchy::train::LmTrainer;
    let mut trainer = LmTrainer::new(rt, "tiny", 3).unwrap();
    let mut corpus = MarkovCorpus::new(trainer.vocab, 11);
    let shapes = trainer.shapes.clone();
    let mut opt = Adam::new(&shapes, 5e-3);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..25 {
        let (loss, _) = trainer.step(&mut opt, &mut corpus, 2).unwrap();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < first - 0.1,
        "loss did not decrease: {first} -> {last}"
    );
    assert_eq!(opt.steps(), 25);
}
