//! Fixture: a dotted lookup that drifted from the known-keys registry.

pub struct Cfg;

impl Cfg {
    pub fn ensure_known_keys(&self, _section: &str, _keys: &[&str]) -> Result<(), String> {
        Ok(())
    }

    pub fn usize_or(&self, _dotted: &str, default: usize) -> usize {
        default
    }
}

pub fn resolve(cfg: &Cfg) -> Result<usize, String> {
    cfg.ensure_known_keys("train", &["steps", "lr"])?;
    let steps = cfg.usize_or("train.steps", 100);
    let warmup = cfg.usize_or("train.warmup", 10);
    Ok(steps + warmup)
}
