//! Fixture: PROTO_VERSION bumped past the degrade-matrix list.

pub const PROTO_VERSION: u32 = 3;
