pub fn degrade_matrix_is_stale() {
    for proto in [1u32, PROTO_VERSION] { // lint:degrade-matrix
        let _ = proto;
    }
}
