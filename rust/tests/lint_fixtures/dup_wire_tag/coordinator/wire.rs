//! Fixture: two wire tags share a byte value.

const TAG_HELLO: u8 = 1;
const TAG_BYE: u8 = 1;

pub fn encode_frame(kind: bool) -> Vec<u8> {
    vec![if kind { TAG_HELLO } else { TAG_BYE }]
}

pub fn decode_payload(b: &[u8]) -> u8 {
    match b[0] {
        TAG_HELLO => 0,
        TAG_BYE => 1,
        _ => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip() {
        assert!(decode_payload(&encode_frame(true)) <= TAG_HELLO);
        assert!(decode_payload(&encode_frame(false)) <= TAG_BYE);
    }
}
