//! Fixture: raw as_f64 read in gate code.

pub enum Json {
    Num(f64),
    Null,
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Null => None,
        }
    }
}

pub fn positive(j: &Json) -> Option<f64> {
    j.as_f64().filter(|&x| x > 0.0)
}
