//! Fixture: seeded-order hash structures in the deterministic core.

use std::collections::HashMap;

pub fn histogram(xs: &[u32]) -> usize {
    let mut seen: HashMap<u32, u32> = HashMap::new();
    for &x in xs {
        *seen.entry(x).or_insert(0) += 1;
    }
    seen.len()
}
