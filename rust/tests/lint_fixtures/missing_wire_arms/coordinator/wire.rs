//! Fixture: a tag with a decode arm but no encode arm and no test.

const TAG_HELLO: u8 = 1;
const TAG_ORPHAN: u8 = 2;

pub fn encode_frame() -> Vec<u8> {
    vec![TAG_HELLO]
}

pub fn decode_payload(b: &[u8]) -> u8 {
    match b[0] {
        TAG_HELLO => 0,
        TAG_ORPHAN => 1,
        _ => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrips() {
        assert_eq!(decode_payload(&encode_frame()), 0);
        let _ = TAG_HELLO;
    }
}
