pub fn wall_nanos() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
