//! Fixture: allowlist suppression plus stale/non-allowlistable entries.

pub fn fine() -> u32 {
    7
}
