//! Fixture: decode-path prealloc sized by an unvalidated length field.

pub fn decode_block(b: &[u8]) -> Vec<u64> {
    let n = b[0] as usize;
    let mut out = Vec::with_capacity(n);
    for chunk in b[1..].chunks(8).take(n) {
        let mut word = [0u8; 8];
        word.copy_from_slice(chunk);
        out.push(u64::from_le_bytes(word));
    }
    out
}
