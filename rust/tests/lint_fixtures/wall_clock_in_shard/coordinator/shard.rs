//! Fixture: raw wall clock in coordinator production code.

pub fn step_latency_nanos() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}
