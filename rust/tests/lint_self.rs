//! Self-tests for `sketchy lint`.
//!
//! Each committed fixture under `tests/lint_fixtures/` is a tiny tree
//! that violates exactly one rule family; the engine must report the
//! expected rule id at the expected `file:line` — no more, no less.
//! The final test runs the linter over HEAD itself in repo mode and
//! asserts the tree is clean, so any future violation fails `cargo
//! test` as well as the CI lint leg.

use std::path::{Path, PathBuf};

use sketchy::analysis::lint_root;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name)
}

/// Lint one fixture and assert its exact (rule, path, line) triples.
fn expect(name: &str, want: &[(&str, &str, usize)]) {
    let report = lint_root(&fixture(name)).unwrap();
    let got: Vec<(String, String, usize)> = report
        .violations
        .iter()
        .map(|v| (v.rule.to_string(), v.path.clone(), v.line))
        .collect();
    let want: Vec<(String, String, usize)> = want
        .iter()
        .map(|&(r, p, l)| (r.to_string(), p.to_string(), l))
        .collect();
    assert_eq!(got, want, "fixture {name}:\n{}", report.render());
}

#[test]
fn wall_clock_in_shard_trips_dt001() {
    expect(
        "wall_clock_in_shard",
        &[("DT001", "coordinator/shard.rs", 4)],
    );
}

#[test]
fn hashmap_in_optim_trips_dt002() {
    expect(
        "hashmap_in_optim",
        &[("DT002", "optim/engine.rs", 3), ("DT002", "optim/engine.rs", 6)],
    );
}

#[test]
fn duplicate_tag_value_trips_wt001() {
    expect("dup_wire_tag", &[("WT001", "coordinator/wire.rs", 4)]);
}

#[test]
fn orphan_tag_trips_wt002_and_wt003() {
    expect(
        "missing_wire_arms",
        &[
            ("WT002", "coordinator/wire.rs", 4),
            ("WT003", "coordinator/wire.rs", 4),
        ],
    );
}

#[test]
fn stale_degrade_matrix_trips_wt004() {
    expect(
        "degrade_matrix",
        &[("WT004", "tests/shard_determinism.rs", 2)],
    );
}

#[test]
fn unbounded_decode_prealloc_trips_ab001() {
    expect(
        "unbounded_decode_alloc",
        &[("AB001", "coordinator/wire.rs", 5)],
    );
}

#[test]
fn unregistered_config_key_trips_ck001() {
    expect("config_key_drift", &[("CK001", "util/settings.rs", 18)]);
}

#[test]
fn raw_as_f64_in_gate_trips_fl001() {
    expect("float_audit", &[("FL001", "util/gate.rs", 18)]);
}

#[test]
fn allowlist_suppresses_and_flags_stale_entries() {
    let report = lint_root(&fixture("stale_allowlist")).unwrap();
    assert_eq!(report.allow_used, 1, "{}", report.render());
    let got: Vec<(&str, &str, usize)> = report
        .violations
        .iter()
        .map(|v| (v.rule, v.path.as_str(), v.line))
        .collect();
    // The live DT001 exception is consumed silently; the stale entry and
    // the non-allowlistable WT001 entry each fail the lint themselves.
    assert_eq!(
        got,
        vec![("AL001", "lint_allow.txt", 2), ("AL001", "lint_allow.txt", 3)],
        "{}",
        report.render()
    );
}

#[test]
fn head_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let report = lint_root(root).unwrap();
    assert!(
        report.clean(),
        "HEAD must pass `sketchy lint`:\n{}",
        report.render()
    );
    assert!(
        report.files_scanned > 40,
        "suspiciously small scan ({} files) — repo-mode discovery broke",
        report.files_scanned
    );
    assert_eq!(
        report.allow_used, 2,
        "expected exactly the two audited bench-harness clock exceptions"
    );
}
