//! The persistent worker-pool runtime and the RefreshAhead overlap
//! stage: determinism, lifecycle, and failure-surfacing contracts.
//!
//! The pool never decides *what* is computed, only *where* — so every
//! pooled path (dense kernels, engine block phases, background refresh
//! jobs) must be **bitwise identical** to its pinned-serial reference,
//! and a worker panic must surface as an error naming the task instead
//! of wedging the phase. The CI `SKETCHY_THREADS: [1, 4]` matrix runs
//! this whole suite at both thread counts; within one process the
//! serial reference is driven through the `with_single_thread` pin
//! (thread count 1), which takes exactly the code path `SKETCHY_THREADS
//! = 1` takes.

use sketchy::coordinator::wire::PROTO_VERSION;
use sketchy::coordinator::{FaultInjectingTransport, FaultScript};
use sketchy::optim::{
    EngineConfig, ExecutorBuilder, GraftType, Optimizer, PrecondEngine, ShampooConfig, UnitKind,
};
use sketchy::runtime::WorkerPool;
use sketchy::sketch::FdSketch;
use sketchy::tensor::ops::{self, with_single_thread};
use sketchy::tensor::{a_at, at_a, at_b, matmul, Matrix};
use sketchy::util::rng::Pcg64;
use std::sync::Arc;

fn assert_bitwise_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape mismatch");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

#[test]
fn pooled_kernels_bitwise_match_pinned_serial() {
    // Sizes crossing the parallel threshold so the pool path actually
    // dispatches (under SKETCHY_THREADS=1 both sides are serial and the
    // assertion is trivially true — that leg pins the env contract).
    let mut rng = Pcg64::new(520);
    let a = Matrix::randn(300, 120, &mut rng);
    let b = Matrix::randn(120, 300, &mut rng);
    assert_bitwise_eq(&matmul(&a, &b), &with_single_thread(|| matmul(&a, &b)), "matmul");
    assert_bitwise_eq(&at_a(&a), &with_single_thread(|| at_a(&a)), "at_a");
    assert_bitwise_eq(&a_at(&a), &with_single_thread(|| a_at(&a)), "a_at");
    let c = Matrix::randn(300, 80, &mut rng);
    assert_bitwise_eq(&at_b(&a, &c), &with_single_thread(|| at_b(&a, &c)), "at_b");
}

#[test]
fn fd_sketch_update_unchanged_by_pooled_kernels() {
    // The FD update (Gram build + eigh + deflation) sits on top of the
    // covariance kernels; pooled dispatch must leave its results
    // untouched bit for bit. Sizes chosen so the update's Gram and
    // basis-rotation kernels cross the parallel threshold
    // (256·96²/2 and 256·96·96 are both ≥ 2²⁰).
    let mut rng = Pcg64::new(521);
    let news: Vec<Matrix> = (0..2).map(|_| Matrix::randn(256, 96, &mut rng)).collect();
    let mut pooled = FdSketch::new(256, 32, 0.999);
    let mut pinned = FdSketch::new(256, 32, 0.999);
    for y in &news {
        pooled.update(y);
        with_single_thread(|| pinned.update(y));
    }
    assert_eq!(pooled.escaped_mass().to_bits(), pinned.escaped_mass().to_bits());
    let (wp, ws) = (pooled.eigenvalues(), pinned.eigenvalues());
    assert_eq!(wp.len(), ws.len());
    for (x, y) in wp.iter().zip(ws.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "eigenvalue diverged");
    }
}

fn base_cfg() -> ShampooConfig {
    ShampooConfig {
        lr: 0.05,
        start_preconditioning_step: 3,
        stat_interval: 2,
        graft: GraftType::Rmsprop,
        clip: 5.0,
        weight_decay: 1e-3,
        ..Default::default()
    }
}

fn random_grads(shapes: &[(usize, usize)], rng: &mut Pcg64) -> Vec<Matrix> {
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, rng)).collect()
}

#[test]
fn pool_backed_engine_bitwise_matches_serial() {
    // The pool-backed engine step (threads = 4) against the serial
    // reference (threads = 1) over 50 steps — the PR-2 scoped-thread
    // contract, now running on persistent workers.
    let shapes = [(11, 7), (6, 6), (9, 1)];
    let mk = |threads: usize| {
        let ecfg = EngineConfig {
            threads,
            block_size: 4,
            refresh_interval: 3,
            stagger: true,
            ..Default::default()
        };
        PrecondEngine::shampoo(&shapes, base_cfg(), ecfg)
    };
    let mut serial = mk(1);
    let mut pooled = mk(4);
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(522);
    for step in 0..50 {
        let grads = random_grads(&shapes, &mut rng);
        serial.step(&mut p1, &grads);
        pooled.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "pooled engine diverged at step {step}");
        }
    }
    assert_eq!(serial.refreshes(), pooled.refreshes());
}

/// Drive an overlap engine and a synchronous engine over one gradient
/// stream; parameters must match bitwise after every step and refresh
/// accounting must agree at the end.
fn assert_overlap_matches_sync(
    shapes: &[(usize, usize)],
    make: impl Fn(EngineConfig) -> PrecondEngine,
    ecfg: EngineConfig,
    steps: usize,
    seed: u64,
) {
    let mut sync = make(EngineConfig { overlap: false, ..ecfg });
    let mut over = make(EngineConfig { overlap: true, ..ecfg });
    assert!(over.name().contains("overlap"), "name should mark overlap: {}", over.name());
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(seed);
    for step in 0..steps {
        let grads = random_grads(shapes, &mut rng);
        sync.step(&mut p1, &grads);
        over.step(&mut p2, &grads);
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "overlap diverged from sync at step {step}");
        }
    }
    assert_eq!(
        sync.refreshes(),
        over.refreshes(),
        "refresh accounting must survive the RefreshAhead handoff"
    );
    assert!(sync.refreshes() > 0, "test must exercise refreshes");
}

#[test]
fn overlap_refresh_bitwise_matches_synchronous_shampoo() {
    let shapes = [(12, 8), (6, 5)];
    let ecfg = EngineConfig {
        threads: 3,
        block_size: 4,
        refresh_interval: 2,
        stagger: true,
        ..Default::default()
    };
    assert_overlap_matches_sync(
        &shapes,
        |e| PrecondEngine::shampoo(&shapes, base_cfg(), e),
        ecfg,
        50,
        523,
    );
}

#[test]
fn overlap_refresh_bitwise_matches_synchronous_sketched() {
    let shapes = [(10, 6)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 5,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    assert_overlap_matches_sync(
        &shapes,
        |e| PrecondEngine::sketched(&shapes, 3, base_cfg(), e),
        ecfg,
        50,
        524,
    );
}

#[test]
fn overlap_degrades_to_synchronous_when_every_step_ingests() {
    // stat_interval = 1: every next step folds statistics, so nothing
    // is ever prefetchable — overlap mode must quietly run the fully
    // synchronous schedule (and still match it, trivially).
    let shapes = [(8, 8)];
    let base = ShampooConfig { stat_interval: 1, ..base_cfg() };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 2,
        stagger: true,
        ..Default::default()
    };
    assert_overlap_matches_sync(
        &shapes,
        |e| PrecondEngine::shampoo(&shapes, base.clone(), e),
        ecfg,
        20,
        525,
    );
}

#[test]
fn overlap_without_stagger_matches_synchronous() {
    // Stagger off: refresh slots bunch on every refresh_interval-th
    // step; with stat_interval 2 and refresh_interval 3, due steps
    // alternate between prefetchable and not.
    let shapes = [(9, 9)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 3,
        refresh_interval: 3,
        stagger: false,
        ..Default::default()
    };
    assert_overlap_matches_sync(
        &shapes,
        |e| PrecondEngine::shampoo(&shapes, base_cfg(), e),
        ecfg,
        30,
        526,
    );
}

/// A sharded engine over the in-memory harness (fault-free), for the
/// accounting-parity tests: same worker protocol as real processes, no
/// sockets, so this runs inside the regular test budget.
fn in_proc_sharded_engine(shards: usize, ecfg: EngineConfig, proto: u32) -> PrecondEngine {
    let shapes = [(10usize, 8usize), (6, 5)];
    let transports: Vec<Arc<FaultInjectingTransport>> =
        (0..shards).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
    // Delta-compressed payloads on (inert below wire protocol v3): the
    // accounting-parity contract must hold over the compressed wire too.
    ExecutorBuilder::in_proc(transports, proto, true)
        .build(&shapes, UnitKind::Shampoo, base_cfg(), ecfg)
        .expect("launch in-proc sharded engine")
}

#[test]
fn sharded_overlap_refresh_accounting_matches_sync_and_local() {
    // Satellite: the pool_runtime refresh-accounting contract, extended
    // to the sharded path — per-step parameters and total refresh
    // counts must agree across the in-process engine, the sharded sync
    // engine, and the sharded overlap engine (RefreshAhead counts
    // crossing the wire must neither drop nor double).
    let shapes = [(10usize, 8usize), (6, 5)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 2,
        stagger: true,
        ..Default::default()
    };
    let mut local = PrecondEngine::shampoo(&shapes, base_cfg(), ecfg);
    let mut shard_sync = in_proc_sharded_engine(2, ecfg, PROTO_VERSION);
    let mut shard_over =
        in_proc_sharded_engine(2, EngineConfig { overlap: true, ..ecfg }, PROTO_VERSION);
    assert!(shard_over.name().contains("overlap"), "name: {}", shard_over.name());
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut p3 = p1.clone();
    let mut rng = Pcg64::new(527);
    for step in 0..30 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        shard_over.try_step(&mut p3, &grads).expect("sharded overlap step");
        shard_sync.try_step(&mut p2, &grads).expect("sharded sync step");
        for ((a, b), c) in p1.iter().zip(&p2).zip(&p3) {
            assert_eq!(a.max_diff(b), 0.0, "sharded sync diverged at step {step}");
            assert_eq!(a.max_diff(c), 0.0, "sharded overlap diverged at step {step}");
        }
    }
    assert_eq!(local.refreshes(), shard_sync.refreshes(), "sync sharded accounting");
    assert_eq!(local.refreshes(), shard_over.refreshes(), "overlap sharded accounting");
    assert!(local.refreshes() > 0, "test must exercise refreshes");
}

#[test]
fn sharded_overlap_latches_off_cleanly_without_worker_capability() {
    // Satellite: a worker fleet that reports no RefreshAhead capability
    // (wire protocol v1) must resolve the overlap knob off at
    // construction — visibly (no "+overlap" in the name) — and still
    // run bitwise identically with identical refresh accounting.
    let shapes = [(10usize, 8usize), (6, 5)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 2,
        stagger: true,
        overlap: true,
        ..Default::default()
    };
    let mut degraded = in_proc_sharded_engine(2, ecfg, 1);
    assert!(
        !degraded.name().contains("overlap"),
        "overlap must latch off for v1 workers: {}",
        degraded.name()
    );
    let mut local =
        PrecondEngine::shampoo(&shapes, base_cfg(), EngineConfig { overlap: false, ..ecfg });
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(528);
    for step in 0..12 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        degraded.try_step(&mut p2, &grads).expect("degraded sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "degraded run diverged at step {step}");
        }
    }
    assert_eq!(local.refreshes(), degraded.refreshes());
}

#[test]
fn pool_shutdown_and_reentry() {
    // Drop + rebuild: a pool joins its workers on drop and a fresh pool
    // (same process) serves new phases — the lifecycle the engine's
    // drop/rebuild path depends on.
    use std::sync::atomic::{AtomicU64, Ordering};
    let out: Vec<AtomicU64> = (0..32).map(|_| AtomicU64::new(0)).collect();
    let pool = WorkerPool::new(3);
    pool.run(3, 32, |i| {
        out[i].store((i * i) as u64, Ordering::Relaxed);
    });
    drop(pool);
    let pool = WorkerPool::new(2);
    pool.run(2, 32, |i| {
        out[i].fetch_add(i as u64, Ordering::Relaxed);
    });
    drop(pool);
    for (i, v) in out.iter().enumerate() {
        assert_eq!(v.load(Ordering::Relaxed), (i * i + i) as u64, "task {i} result");
    }
}

#[test]
fn worker_panic_surfaces_as_error_naming_the_task() {
    let pool = WorkerPool::new(2);
    let err = pool
        .try_run(3, 10, |i| {
            if i == 7 {
                panic!("eigh exploded");
            }
        })
        .expect_err("panicking task must fail the phase");
    assert!(err.contains("task 7"), "error must name the task: {err}");
    assert!(err.contains("eigh exploded"), "error must carry the message: {err}");
    // The phase still completed and the pool is reusable.
    pool.run(3, 10, |_| {});
}

#[test]
fn global_pool_grows_with_engine_pool_threads_knob() {
    let before = sketchy::runtime::pool::global().workers();
    let ecfg = EngineConfig { pool_threads: 2, ..Default::default() };
    let _eng = PrecondEngine::shampoo(&[(4, 4)], base_cfg(), ecfg);
    let after = sketchy::runtime::pool::global().workers();
    assert!(after >= 2.max(before), "pool must be pre-sized: {before} -> {after}");
    // And the thread resolution the kernels use is cached + stable.
    assert_eq!(ops::num_threads(), ops::num_threads());
}
