//! Cross-process shard engine: bitwise determinism and failure handling.
//!
//! The contract under test: partitioning preconditioner blocks across
//! `sketchy shard-worker` processes is an *execution* decision, never a
//! numeric one — a 2-shard or 4-shard run must produce parameters
//! **bitwise identical** to the in-process engine, for every unit kind
//! and transport. These tests spawn real worker processes from the
//! built `sketchy` binary (`CARGO_BIN_EXE_sketchy`); the CI
//! `shard-smoke` job runs them in release mode.

use sketchy::coordinator::shard::{FleetStats, ShardExecutor, ShardLaunch, ShardTransport};
use sketchy::coordinator::wire::PROTO_VERSION;
use sketchy::coordinator::{
    FaultAction, FaultInjectingTransport, FaultScript, LinkTimeouts, MembershipConfig, VirtualClock,
};
use sketchy::optim::precond::StepCtx;
use sketchy::optim::{
    partition, Adam, BlockExecutor, EngineConfig, ExecutorBuilder, GraftType, LocalExecutor,
    Optimizer, PrecondEngine, ShampooConfig, UnitKind,
};
use sketchy::tensor::Matrix;
use sketchy::train::{load_checkpoint_full, load_journal, save_checkpoint_with_state};
use sketchy::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn sketchy_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_sketchy"))
}

fn mk_launch(shards: usize, transport: ShardTransport) -> ShardLaunch {
    ShardLaunch {
        program: sketchy_bin(),
        shards,
        transport,
        proto: PROTO_VERSION,
        compress: false,
        launch: None,
        membership: MembershipConfig::default(),
    }
}

/// Builder-era local engine (the old `PrecondEngine::new`).
fn local_engine(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    base: ShampooConfig,
    ecfg: EngineConfig,
) -> PrecondEngine {
    ExecutorBuilder::local().build(shapes, kind, base, ecfg).expect("build local engine")
}

/// Builder-era process-sharded engine (the old `PrecondEngine::sharded`).
fn sharded_engine(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    base: ShampooConfig,
    ecfg: EngineConfig,
    launch: &ShardLaunch,
) -> anyhow::Result<PrecondEngine> {
    ExecutorBuilder::sharded(launch.clone()).build(shapes, kind, base, ecfg)
}

/// Builder-era in-proc harness engine (the old `with_executor` over
/// `launch_in_proc`).
fn in_proc_engine(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    base: ShampooConfig,
    ecfg: EngineConfig,
    transports: &[Arc<FaultInjectingTransport>],
    proto: u32,
    compress: bool,
) -> anyhow::Result<PrecondEngine> {
    ExecutorBuilder::in_proc(transports.to_vec(), proto, compress).build(shapes, kind, base, ecfg)
}

fn base_cfg() -> ShampooConfig {
    ShampooConfig {
        lr: 0.05,
        start_preconditioning_step: 2,
        graft: GraftType::Rmsprop,
        clip: 5.0,
        weight_decay: 1e-3,
        ..Default::default()
    }
}

fn random_grads(shapes: &[(usize, usize)], rng: &mut Pcg64) -> Vec<Matrix> {
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, rng)).collect()
}

/// Step the in-process engine and an N-shard engine on one gradient
/// stream; assert bitwise-equal parameters after every step and equal
/// refresh accounting at the end.
fn assert_sharded_matches_local(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    block_size: usize,
    shards: usize,
    transport: ShardTransport,
    steps: usize,
    seed: u64,
) {
    let ecfg = EngineConfig {
        threads: 2,
        block_size,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let mut local = local_engine(shapes, kind, base_cfg(), ecfg);
    let mut sharded = sharded_engine(shapes, kind, base_cfg(), ecfg, &mk_launch(shards, transport))
        .expect("launch sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(seed);
    for step in 0..steps {
        let grads = random_grads(shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(
                a.max_diff(b),
                0.0,
                "{shards}-shard run diverged from in-process engine at step {step}"
            );
        }
    }
    assert_eq!(
        local.refreshes(),
        sharded.refreshes(),
        "refresh accounting must survive the wire"
    );
}

#[test]
fn two_shard_tcp_matches_single_process_bitwise() {
    let shapes = [(10, 7), (6, 6), (9, 1)];
    assert_sharded_matches_local(&shapes, UnitKind::Shampoo, 4, 2, ShardTransport::Tcp, 12, 410);
}

#[test]
fn four_shard_tcp_matches_single_process_bitwise() {
    let shapes = [(12, 10), (8, 3)];
    assert_sharded_matches_local(
        &shapes,
        UnitKind::Sketched { rank: 3 },
        5,
        4,
        ShardTransport::Tcp,
        12,
        411,
    );
}

#[cfg(unix)]
#[test]
fn two_shard_unix_socket_matches_single_process_bitwise() {
    let shapes = [(8, 8), (5, 4)];
    assert_sharded_matches_local(&shapes, UnitKind::Shampoo, 4, 2, ShardTransport::Unix, 8, 412);
}

#[test]
fn sharded_engine_adam_equals_fused_adam() {
    // The Adam normalization path (grafting / driver momentum stripped)
    // must survive the wire: a 2-shard engine-adam reproduces the fused
    // Adam bitwise across an arbitrary block partition.
    let shapes = [(5, 4), (3, 3)];
    let mut fused = Adam::new(&shapes, 0.05);
    fused.weight_decay = 0.01;
    fused.clip = 1.0;
    let base = ShampooConfig {
        lr: 0.05,
        beta2: 0.999,
        weight_decay: 0.01,
        clip: 1.0,
        beta1: 0.9,
        start_preconditioning_step: 7,
        stat_interval: 2,
        precond_interval: 3,
        graft: GraftType::RmspropNormalized,
        ..Default::default()
    };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 2,
        refresh_interval: 1,
        stagger: false,
        ..Default::default()
    };
    let mut engine =
        sharded_engine(&shapes, UnitKind::Adam, base, ecfg, &mk_launch(2, ShardTransport::Tcp))
            .expect("launch sharded adam engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(413);
    for step in 0..15 {
        let grads = random_grads(&shapes, &mut rng);
        fused.step(&mut p1, &grads);
        engine.try_step(&mut p2, &grads).expect("sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "sharded engine-adam diverged at step {step}");
        }
    }
}

/// A config where prefetchable steps exist (`stat_interval` 2: odd
/// steps fold no statistics), so RefreshAhead has real work to overlap.
fn overlap_base() -> ShampooConfig {
    ShampooConfig { stat_interval: 2, ..base_cfg() }
}

/// Step three engines — in-process sync (the reference), sharded sync,
/// and sharded overlap — on one gradient stream; assert all three are
/// bitwise identical after every step and agree on refresh accounting.
fn assert_overlap_sharded_matches_sync_and_local(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    block_size: usize,
    shards: usize,
    steps: usize,
    seed: u64,
) {
    let ecfg = EngineConfig {
        threads: 2,
        block_size,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let overlap_ecfg = EngineConfig { overlap: true, ..ecfg };
    let mut local = local_engine(shapes, kind, overlap_base(), ecfg);
    let mut shard_sync =
        sharded_engine(shapes, kind, overlap_base(), ecfg, &mk_launch(shards, ShardTransport::Tcp))
            .expect("launch sync sharded engine");
    let mut shard_over = sharded_engine(
        shapes,
        kind,
        overlap_base(),
        overlap_ecfg,
        &mk_launch(shards, ShardTransport::Tcp),
    )
    .expect("launch overlap sharded engine");
    assert!(
        shard_over.name().contains("overlap"),
        "v2 workers must keep the overlap knob on: {}",
        shard_over.name()
    );
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut p3 = p1.clone();
    let mut rng = Pcg64::new(seed);
    for step in 0..steps {
        let grads = random_grads(shapes, &mut rng);
        local.step(&mut p1, &grads);
        shard_sync.try_step(&mut p2, &grads).expect("sync sharded step");
        shard_over.try_step(&mut p3, &grads).expect("overlap sharded step");
        for ((a, b), c) in p1.iter().zip(&p2).zip(&p3) {
            assert_eq!(
                a.max_diff(b),
                0.0,
                "{shards}-shard sync run diverged from in-process at step {step}"
            );
            assert_eq!(
                a.max_diff(c),
                0.0,
                "{shards}-shard overlap run diverged from in-process at step {step}"
            );
        }
    }
    assert_eq!(local.refreshes(), shard_sync.refreshes(), "sync refresh accounting");
    assert_eq!(
        local.refreshes(),
        shard_over.refreshes(),
        "overlap refresh accounting must survive the RefreshAhead handoff"
    );
    assert!(local.refreshes() > 0, "test must exercise refreshes");
}

#[test]
fn two_shard_overlap_matches_sync_sharded_and_local_bitwise() {
    let shapes = [(10, 7), (6, 6), (9, 1)];
    assert_overlap_sharded_matches_sync_and_local(&shapes, UnitKind::Shampoo, 4, 2, 12, 420);
}

#[test]
fn four_shard_overlap_matches_sync_sharded_and_local_bitwise() {
    let shapes = [(12, 10), (8, 3)];
    assert_overlap_sharded_matches_sync_and_local(
        &shapes,
        UnitKind::Sketched { rank: 3 },
        5,
        4,
        12,
        421,
    );
}

#[test]
fn legacy_proto_workers_degrade_overlap_to_sync_with_identical_numbers() {
    // Spawn real worker processes pinned to wire protocol v1: they
    // greet with the legacy Hello, the engine resolves the overlap knob
    // off (logged notice), and the run stays bitwise identical to the
    // in-process engine.
    let shapes = [(8usize, 8usize), (5, 4)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        overlap: true,
        ..Default::default()
    };
    let launch = ShardLaunch {
        program: sketchy_bin(),
        shards: 2,
        transport: ShardTransport::Tcp,
        proto: 1,
        compress: true, // inert below v3 — part of the degrade matrix
        launch: None,
        membership: MembershipConfig::default(),
    };
    let mut local = local_engine(
        &shapes,
        UnitKind::Shampoo,
        overlap_base(),
        EngineConfig { overlap: false, ..ecfg },
    );
    let mut sharded = sharded_engine(&shapes, UnitKind::Shampoo, overlap_base(), ecfg, &launch)
        .expect("launch v1 sharded engine");
    assert!(
        !sharded.name().contains("overlap"),
        "v1 workers must resolve the overlap knob off: {}",
        sharded.name()
    );
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(422);
    for step in 0..8 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("degraded sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "degraded run diverged at step {step}");
        }
    }
    assert_eq!(local.refreshes(), sharded.refreshes());
}

// ---------------------------------------------------------------------------
// Fault-injection chaos: the in-memory harness, no sockets involved.
// ---------------------------------------------------------------------------

const CHAOS_SHAPES: [(usize, usize); 2] = [(8, 6), (5, 5)];
const CHAOS_STEPS: usize = 8;

fn chaos_ecfg(overlap: bool) -> EngineConfig {
    EngineConfig {
        threads: 1,
        block_size: 4,
        refresh_interval: 2,
        stagger: true,
        overlap,
        ..Default::default()
    }
}

/// Run the overlap engine over in-proc harness workers with the given
/// per-shard fault scripts at the given wire protocol (compression on
/// from v3 when `compress`); return final params + refresh count.
fn chaos_run(
    proto: u32,
    compress: bool,
    scripts: Vec<FaultScript>,
    max_connections: usize,
) -> anyhow::Result<(Vec<Matrix>, usize)> {
    // A 2s read-timeout cap: long enough that parallel-test scheduling
    // stalls never masquerade as faults, short enough that a scripted
    // DropFrame resolves quickly. (Recovery is idempotent either way —
    // the cap only shapes test latency.)
    let transports: Vec<Arc<FaultInjectingTransport>> = scripts
        .into_iter()
        .map(|s| {
            FaultInjectingTransport::with_config(s, max_connections, Some(Duration::from_secs(2)))
        })
        .collect();
    let mut eng = in_proc_engine(
        &CHAOS_SHAPES,
        UnitKind::Shampoo,
        overlap_base(),
        chaos_ecfg(true),
        &transports,
        proto,
        compress,
    )?;
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for _ in 0..CHAOS_STEPS {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads)?;
    }
    Ok((params, eng.refreshes()))
}

/// PR-4 shape of the chaos runner: current protocol, full frames.
fn chaos_overlap_run(
    scripts: Vec<FaultScript>,
    max_connections: usize,
) -> anyhow::Result<(Vec<Matrix>, usize)> {
    chaos_run(PROTO_VERSION, false, scripts, max_connections)
}

/// The fault-free reference: the plain in-process synchronous engine on
/// the same stream.
fn chaos_reference() -> (Vec<Matrix>, usize) {
    let mut eng =
        local_engine(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(false));
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for _ in 0..CHAOS_STEPS {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.step(&mut params, &grads);
    }
    (params, eng.refreshes())
}

fn assert_matches_reference(
    got: &(Vec<Matrix>, usize),
    want: &(Vec<Matrix>, usize),
    what: &str,
) {
    for (i, (a, b)) in want.0.iter().zip(&got.0).enumerate() {
        assert_eq!(a.max_diff(b), 0.0, "{what}: tensor {i} diverged from reference");
    }
    assert_eq!(want.1, got.1, "{what}: refresh accounting diverged");
}

#[test]
fn overlap_over_clean_in_proc_harness_matches_reference() {
    let want = chaos_reference();
    let got = chaos_overlap_run(vec![FaultScript::none(), FaultScript::none()], usize::MAX)
        .expect("fault-free harness run");
    assert_matches_reference(&got, &want, "clean harness");
    assert!(want.1 > 0, "test must exercise refreshes");
}

#[test]
fn overlap_survives_severing_every_request_frame_bitwise() {
    // The acceptance sweep: sever shard 0's link at every scripted
    // request-frame index in turn — in particular every gap between a
    // RefreshAhead RPC and the following Step — and assert the
    // reconnect + idempotent-replay path reproduces the reference run
    // bit for bit, refresh accounting included. The 8-step run sends
    // ~17 request frames per shard (Init, then Step + RefreshAhead per
    // step); sweeping past the end just proves a fault that never fires
    // is harmless.
    let want = chaos_reference();
    for fault_at in 0..20 {
        let script = FaultScript::none().on_request(fault_at, FaultAction::Sever);
        let got = chaos_overlap_run(vec![script, FaultScript::none()], usize::MAX)
            .unwrap_or_else(|e| panic!("sever at request {fault_at}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("sever at request frame {fault_at}"));
    }
}

#[test]
fn overlap_survives_severing_reply_frames_bitwise() {
    // Same sweep on the worker → driver direction (replies + hellos):
    // the driver loses replies — including parked RefreshAhead replies —
    // mid-flight and must recover through replay without double
    // counting.
    let want = chaos_reference();
    for fault_at in 0..20 {
        let script = FaultScript::none().on_reply(fault_at, FaultAction::Sever);
        let got = chaos_overlap_run(vec![FaultScript::none(), script], usize::MAX)
            .unwrap_or_else(|e| panic!("sever at reply {fault_at}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("sever at reply frame {fault_at}"));
    }
}

#[test]
fn overlap_survives_dropped_and_delayed_frames_bitwise() {
    // (Outright frame *duplication* is exercised at the worker protocol
    // level — see `duplicated_requests_are_absorbed_by_the_reply_caches`
    // in coordinator::shard — because a strict request/response channel
    // never legitimately sees an unsolicited duplicate; the realistic
    // duplicate is a replay after reconnect, which the delay/sever
    // scenarios here produce.)
    let want = chaos_reference();
    for (what, script) in [
        // Drop a mid-run request (lands in the RefreshAhead/Step
        // cadence): the reply wait times out, the driver replays.
        ("drop request 5", FaultScript::none().on_request(5, FaultAction::DropFrame)),
        // Drop a mid-run reply: same recovery from the other side.
        ("drop reply 6", FaultScript::none().on_reply(6, FaultAction::DropFrame)),
        // Delay a request: it is withheld, the reply wait times out, and
        // the stash dies with the abandoned connection — the worker then
        // sees only the replayed copy on the fresh connection.
        ("delay request 4", FaultScript::none().on_request(4, FaultAction::DelayFrame)),
        // A compound scenario across both directions.
        (
            "drop request 3 + sever reply 9",
            FaultScript::none()
                .on_request(3, FaultAction::DropFrame)
                .on_reply(9, FaultAction::Sever),
        ),
    ] {
        let got = chaos_overlap_run(vec![script, FaultScript::none()], usize::MAX)
            .unwrap_or_else(|e| panic!("{what}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, what);
    }
}

#[test]
fn overlap_permanent_link_loss_surfaces_shard_named_error() {
    // Sever mid-run with a connection budget of 1: the reconnect is
    // refused, so the run must fail — naming the shard — instead of
    // hanging or silently diverging.
    let script = FaultScript::none().on_request(4, FaultAction::Sever);
    let err = match chaos_overlap_run(vec![script, FaultScript::none()], 1) {
        Ok(_) => panic!("run through a permanently lost link must fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 0"), "error must name the lost shard: {msg}");
}

// ---------------------------------------------------------------------------
// Wire protocol v3: delta-compressed payloads — degrade matrix + chaos.
// ---------------------------------------------------------------------------

#[test]
fn compressed_transport_proto_degrade_matrix_matches_reference_bitwise() {
    // The v6 ↔ v5 ↔ v4 ↔ v3 ↔ v2 ↔ v1 degrade matrix with the
    // compression knob held on: v6 workers additionally answer
    // heartbeat probes, v5 workers announce membership, v4 workers
    // serve typed state, v3 workers negotiate delta payloads, v2
    // workers keep full frames (and RefreshAhead), v1 workers degrade
    // all the way to the legacy synchronous protocol — every cell
    // bitwise identical to the fault-free reference, refresh
    // accounting included. Every version from 1 through PROTO_VERSION
    // must be listed — the wire lint's degrade-matrix audit checks the
    // marker line below against the current PROTO_VERSION. (The v7 bump
    // originally shipped without the 6 cell; the lint exists so that
    // class of gap fails mechanically.)
    let want = chaos_reference();
    for proto in [1u32, 2, 3, 4, 5, 6, PROTO_VERSION] { // lint:degrade-matrix
        let got = chaos_run(proto, true, vec![FaultScript::none(), FaultScript::none()], usize::MAX)
            .unwrap_or_else(|e| panic!("proto v{proto} + compress run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("compress-on at proto v{proto}"));
    }
    // Shard count is orthogonal to the payload layer: a 4-shard
    // compressed run holds the same identity.
    let got4 = chaos_run(PROTO_VERSION, true, vec![FaultScript::none(); 4], usize::MAX)
        .unwrap_or_else(|e| panic!("4-shard compress run failed: {e:#}"));
    assert_matches_reference(&got4, &want, "compress-on, 4 shards");
    assert!(want.1 > 0, "test must exercise refreshes");
}

#[test]
fn compressed_stream_survives_severing_every_request_frame_bitwise() {
    // The delta-stream acceptance sweep: sever shard 0's link at every
    // request-frame index in turn — killing delta-encoded Steps, the
    // RefreshAhead gaps between them, and the frames whose loss forces
    // a reconnect mid-baseline — and assert the replay + full-frame
    // resync path reproduces the reference bit for bit.
    let want = chaos_reference();
    for fault_at in 0..20 {
        let script = FaultScript::none().on_request(fault_at, FaultAction::Sever);
        let got =
            chaos_run(PROTO_VERSION, true, vec![script, FaultScript::none()], usize::MAX)
                .unwrap_or_else(|e| panic!("sever at request {fault_at}: run failed: {e:#}"));
        assert_matches_reference(
            &got,
            &want,
            &format!("compressed sever at request frame {fault_at}"),
        );
    }
}

#[test]
fn compressed_stream_survives_severing_every_reply_frame_bitwise() {
    // Same sweep on the worker → driver direction: delta-encoded
    // replies (whose loss desynchronizes the download baseline until
    // the resync) die mid-flight at every index in turn.
    let want = chaos_reference();
    for fault_at in 0..20 {
        let script = FaultScript::none().on_reply(fault_at, FaultAction::Sever);
        let got =
            chaos_run(PROTO_VERSION, true, vec![FaultScript::none(), script], usize::MAX)
                .unwrap_or_else(|e| panic!("sever at reply {fault_at}: run failed: {e:#}"));
        assert_matches_reference(
            &got,
            &want,
            &format!("compressed sever at reply frame {fault_at}"),
        );
    }
}

#[test]
fn compressed_stream_survives_dropped_and_delayed_frames_bitwise() {
    // Drop/delay inside the delta stream: the reply wait times out,
    // the driver replays (worker reply caches absorb any duplicate
    // application), and the next encoded step resyncs with full
    // frames. (Outright duplication is exercised at the worker
    // protocol level — `duplicated_delta_steps_are_served_from_the_
    // reply_cache` in coordinator::shard — because a strict
    // request/response channel never sees an unsolicited duplicate.)
    let want = chaos_reference();
    for (what, script) in [
        ("drop request 5", FaultScript::none().on_request(5, FaultAction::DropFrame)),
        ("drop reply 6", FaultScript::none().on_reply(6, FaultAction::DropFrame)),
        ("delay request 4", FaultScript::none().on_request(4, FaultAction::DelayFrame)),
        (
            "drop request 3 + sever reply 9",
            FaultScript::none()
                .on_request(3, FaultAction::DropFrame)
                .on_reply(9, FaultAction::Sever),
        ),
    ] {
        let got = chaos_run(PROTO_VERSION, true, vec![script, FaultScript::none()], usize::MAX)
            .unwrap_or_else(|e| panic!("{what}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("compressed {what}"));
    }
}

#[test]
fn compressed_sparse_grads_shrink_the_wire_and_stay_bitwise() {
    // An LM-ish workload (a one-sided embedding tensor whose gradient
    // touches a few token columns per step + a dense projection): the
    // delta layer must cut delivered bytes by a wide margin while the
    // run stays bitwise identical to the uncompressed transport.
    let shapes = [(8usize, 64usize), (8, 8)];
    let base = ShampooConfig {
        lr: 1e-3,
        beta1: 0.0,
        weight_decay: 0.0,
        one_sided: true,
        start_preconditioning_step: 2,
        stat_interval: 2,
        graft: GraftType::Rmsprop,
        ..Default::default()
    };
    let ecfg = EngineConfig {
        threads: 1,
        block_size: 16,
        refresh_interval: 2,
        stagger: true,
        ..Default::default()
    };
    let grads_at = |rng: &mut Pcg64| -> Vec<Matrix> {
        let (r, c) = shapes[0];
        let mut emb = vec![0.0f64; r * c];
        for _ in 0..4 {
            let col = rng.below(c);
            for row in 0..r {
                emb[row * c + col] = rng.gaussian();
            }
        }
        vec![Matrix::from_vec(r, c, emb), Matrix::randn(shapes[1].0, shapes[1].1, rng)]
    };
    let run = |compress: bool| -> (Vec<Matrix>, usize, u64) {
        let transports: Vec<Arc<FaultInjectingTransport>> =
            (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
        let mut eng = in_proc_engine(
            &shapes,
            UnitKind::Shampoo,
            base.clone(),
            ecfg,
            &transports,
            PROTO_VERSION,
            compress,
        )
        .expect("launch in-proc engine");
        let mut params: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut rng = Pcg64::new(424);
        for _ in 0..10 {
            let grads = grads_at(&mut rng);
            eng.try_step(&mut params, &grads).expect("step");
        }
        let refreshes = eng.refreshes();
        drop(eng);
        (params, refreshes, transports.iter().map(|t| t.bytes_delivered()).sum())
    };
    let (p_full, r_full, bytes_full) = run(false);
    let (p_comp, r_comp, bytes_comp) = run(true);
    for (i, (a, b)) in p_full.iter().zip(&p_comp).enumerate() {
        assert_eq!(a.max_diff(b), 0.0, "tensor {i}: compressed transport diverged");
    }
    assert_eq!(r_full, r_comp, "refresh accounting diverged");
    assert!(
        (bytes_comp as f64) * 2.0 < bytes_full as f64,
        "delta layer should at least halve this workload's wire bytes \
         (full {bytes_full}, compressed {bytes_comp})"
    );
}

#[test]
fn launch_template_spawns_real_workers_and_stays_bitwise() {
    // The pluggable launcher end to end with a real prefix command
    // (`env VAR=1 {program} {worker_cmd}` — same argv mechanics as an
    // ssh template) driving real worker processes, with compression
    // on: bitwise identical to the in-process engine.
    let shapes = [(8usize, 8usize), (5, 4)];
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let launch = ShardLaunch {
        program: sketchy_bin(),
        shards: 2,
        transport: ShardTransport::Tcp,
        proto: PROTO_VERSION,
        compress: true,
        launch: Some("env SKETCHY_LAUNCH_TEMPLATE_TEST={shard} {program} {worker_cmd}".into()),
        membership: MembershipConfig::default(),
    };
    let mut local = local_engine(&shapes, UnitKind::Shampoo, base_cfg(), ecfg);
    let mut sharded = sharded_engine(&shapes, UnitKind::Shampoo, base_cfg(), ecfg, &launch)
        .expect("launch templated sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(425);
    for step in 0..8 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("templated sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "templated launch diverged at step {step}");
        }
    }
    assert_eq!(local.refreshes(), sharded.refreshes());
}

/// Deterministic per-block contexts for driving executors directly.
fn mk_ctxs(n_blocks: usize, t: usize) -> Vec<StepCtx> {
    (0..n_blocks)
        .map(|i| StepCtx {
            t,
            scale: 1.0,
            preconditioning: t >= 2,
            refresh_due: (t + i % 3) % 3 == 0,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 1e-3,
            stat_due: true,
            graft: GraftType::Rmsprop,
        })
        .collect()
}

#[test]
fn driver_reconnects_after_dropped_connections() {
    // Sever every driver-side connection mid-run: the workers keep
    // their block state across connections, so the run continues and
    // stays bitwise identical to the local executor.
    let shapes = [(6usize, 6usize)];
    let blocks = partition(&shapes, 3);
    let base = base_cfg();
    let mut local = LocalExecutor::new(&blocks, UnitKind::Shampoo, &base, 1);
    let mut exec = ShardExecutor::launch_with(
        &mk_launch(2, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base,
        1,
        &MembershipConfig::default(),
    )
    .expect("launch executor");
    let mut p1 = vec![Matrix::zeros(6, 6)];
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(414);
    for t in 1..=6usize {
        let grads = vec![Matrix::randn(6, 6, &mut rng)];
        let ctxs = mk_ctxs(blocks.len(), t);
        local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
        exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).expect("sharded step");
        assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged at step {t}");
        if t == 3 {
            exec.control().drop_connections();
        }
    }
}

#[test]
fn dead_worker_is_surfaced_with_its_shard_id() {
    let shapes = [(6usize, 6usize)];
    let blocks = partition(&shapes, 3);
    let base = base_cfg();
    let mut exec = ShardExecutor::launch_with(
        &mk_launch(2, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base,
        1,
        &MembershipConfig::default(),
    )
    .expect("launch executor");
    assert_eq!(exec.shards(), 2);
    let mut params = vec![Matrix::zeros(6, 6)];
    let mut rng = Pcg64::new(415);
    let grads = vec![Matrix::randn(6, 6, &mut rng)];
    exec.step_blocks(&blocks, &mut params, &grads, &mk_ctxs(blocks.len(), 1))
        .expect("first step");
    exec.control().kill_worker(1).expect("fault injection");
    let err = exec
        .step_blocks(&blocks, &mut params, &grads, &mk_ctxs(blocks.len(), 2))
        .expect_err("step through a dead worker must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1"), "error must name the dead shard: {msg}");
}

#[test]
fn spawn_failure_is_surfaced() {
    let shapes = [(4usize, 4usize)];
    let blocks = partition(&shapes, 4);
    let bogus = ShardLaunch {
        program: PathBuf::from("/definitely/not/a/real/binary"),
        shards: 1,
        transport: ShardTransport::Tcp,
        proto: PROTO_VERSION,
        compress: true,
        launch: None,
        membership: MembershipConfig::default(),
    };
    let err = match ShardExecutor::launch_with(
        &bogus,
        &blocks,
        UnitKind::Shampoo,
        &base_cfg(),
        1,
        &MembershipConfig::default(),
    ) {
        Ok(_) => panic!("bogus worker binary must fail the launch"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("shard 0"), "got: {err:#}");
}

// ---------------------------------------------------------------------------
// Wire protocol v4: typed block-state payloads — state RPCs, checkpoint
// resume through real workers, mixed-version refusal, state-RPC chaos.
// ---------------------------------------------------------------------------

#[test]
fn v4_checkpoint_resume_through_real_workers_is_bitwise() {
    // The end-to-end sketch-native state story over real worker
    // processes: step a 2-shard Sketched engine in lockstep with the
    // in-process reference, pull the typed snapshot over the v4
    // `StateSnap` RPC (rank-ℓ FD factors, never dense covariance),
    // embed it in a checkpoint-v2 file, kill the workers, relaunch a
    // fresh fleet, restore over `StateRestore`, and continue — the
    // resumed run must track the never-interrupted reference bit for
    // bit.
    let shapes = [(9usize, 6), (5, 4)];
    let kind = UnitKind::Sketched { rank: 3 };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let launch = ShardLaunch {
        program: sketchy_bin(),
        shards: 2,
        transport: ShardTransport::Tcp,
        proto: PROTO_VERSION,
        compress: true,
        launch: None,
        membership: MembershipConfig::default(),
    };
    let mut local = local_engine(&shapes, kind, base_cfg(), ecfg);
    let mut sharded = sharded_engine(&shapes, kind, base_cfg(), ecfg, &launch)
        .expect("launch v4 sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(430);
    for step in 0..5 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "sharded run diverged at step {step}");
        }
    }
    let entries = sharded
        .state_payloads()
        .expect("StateSnap RPC")
        .expect("v4 engines expose typed block state");
    let path = std::env::temp_dir().join(format!("sketchy_v4_resume_{}.ckpt", std::process::id()));
    let path = path.to_str().expect("utf8 temp path").to_string();
    save_checkpoint_with_state(&path, 5, &p2, Some(&entries)).expect("save checkpoint v2");
    drop(sharded); // the worker fleet dies with its driver
    let (step, params, state) = load_checkpoint_full(&path).expect("load checkpoint v2");
    std::fs::remove_file(&path).ok();
    assert_eq!(step, 5, "checkpoint must carry the save step");
    let mut resumed = sharded_engine(&shapes, kind, base_cfg(), ecfg, &launch)
        .expect("relaunch sharded engine");
    resumed
        .restore_payloads(step, state.expect("checkpoint v2 carries typed state"))
        .expect("restore over StateRestore RPC");
    let mut p3 = params;
    for step in 5..10 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        resumed.try_step(&mut p3, &grads).expect("resumed sharded step");
        for (a, b) in p1.iter().zip(&p3) {
            assert_eq!(a.max_diff(b), 0.0, "resumed run diverged at step {step}");
        }
    }
}

#[test]
fn v4_driver_with_v3_workers_steps_bitwise_but_refuses_state_rpcs() {
    // The mixed-version cell of the degrade matrix over real
    // processes: workers pinned to v3 keep the delta-compressed step
    // stream bitwise, but the typed-state capability is absent, so the
    // state RPCs must refuse loudly — and the refusal must not poison
    // the stepping stream.
    let shapes = [(8usize, 8), (5, 4)];
    let kind = UnitKind::Sketched { rank: 3 };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let launch = ShardLaunch {
        program: sketchy_bin(),
        shards: 2,
        transport: ShardTransport::Tcp,
        proto: 3,
        compress: true,
        launch: None,
        membership: MembershipConfig::default(),
    };
    let mut local = local_engine(&shapes, kind, base_cfg(), ecfg);
    let mut sharded = sharded_engine(&shapes, kind, base_cfg(), ecfg, &launch)
        .expect("launch v3 sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(431);
    for step in 0..6 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("v3 sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "v3 run diverged at step {step}");
        }
    }
    let err = sharded.state_payloads().expect_err("v3 workers cannot serve StateSnap");
    assert!(
        format!("{err:#}").contains("below wire protocol v4"),
        "refusal must name the capability gap: {err:#}"
    );
    for step in 6..8 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("post-refusal sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "post-refusal run diverged at step {step}");
        }
    }
    assert_eq!(local.refreshes(), sharded.refreshes());
}

/// Chaos runner for the state RPCs: a Sketched engine over in-proc
/// harness workers steps, snapshots + self-restores mid-run (a pure
/// read followed by an idempotent full-state write), then keeps
/// stepping. Faults land on whatever frame index the script names —
/// including inside the `StateSnap`/`StateRestore` payload streams.
fn sketch_state_chaos_run(
    scripts: Vec<FaultScript>,
    max_connections: usize,
) -> anyhow::Result<(Vec<Matrix>, usize)> {
    let transports: Vec<Arc<FaultInjectingTransport>> = scripts
        .into_iter()
        .map(|s| {
            FaultInjectingTransport::with_config(s, max_connections, Some(Duration::from_secs(2)))
        })
        .collect();
    let mut eng = in_proc_engine(
        &CHAOS_SHAPES,
        UnitKind::Sketched { rank: 2 },
        overlap_base(),
        chaos_ecfg(false),
        &transports,
        PROTO_VERSION,
        true,
    )?;
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(426);
    for _ in 0..4 {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads)?;
    }
    let snaps = eng.state_snapshot()?;
    eng.state_restore(snaps)?;
    for _ in 4..CHAOS_STEPS {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads)?;
    }
    Ok((params, eng.refreshes()))
}

/// Fault-free reference for the state-RPC chaos: the in-process engine
/// on the same stream, snapshot + self-restore included so both runs
/// exercise the identical sequence of state mutations.
fn sketch_state_reference() -> (Vec<Matrix>, usize) {
    let mut eng = local_engine(
        &CHAOS_SHAPES,
        UnitKind::Sketched { rank: 2 },
        overlap_base(),
        chaos_ecfg(false),
    );
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(426);
    for _ in 0..4 {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.step(&mut params, &grads);
    }
    let snaps = eng.state_snapshot().expect("local snapshot");
    eng.state_restore(snaps).expect("local restore");
    for _ in 4..CHAOS_STEPS {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.step(&mut params, &grads);
    }
    (params, eng.refreshes())
}

#[test]
fn v4_state_rpcs_survive_severed_frames_bitwise() {
    // The sketch-payload acceptance sweep: sever the link at every
    // request- and reply-frame index in turn on a run whose stream
    // interleaves delta-compressed Steps with a `StateSnap` +
    // `StateRestore` pair. Severed snapshot replies are re-requested
    // (pure read), severed restore requests are replayed (idempotent
    // full-state overwrite) — every cell must reproduce the reference
    // bit for bit, refresh accounting included. The run sends ~11
    // request frames per shard (Init, 8 Steps, StateSnap,
    // StateRestore); sweeping past the end proves a fault that never
    // fires is harmless.
    let want = sketch_state_reference();
    assert!(want.1 > 0, "test must exercise refreshes");
    for fault_at in 0..14 {
        let script = FaultScript::none().on_request(fault_at, FaultAction::Sever);
        let got = sketch_state_chaos_run(vec![script, FaultScript::none()], usize::MAX)
            .unwrap_or_else(|e| panic!("sever at request {fault_at}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("state-RPC sever at request {fault_at}"));
        let script = FaultScript::none().on_reply(fault_at, FaultAction::Sever);
        let got = sketch_state_chaos_run(vec![FaultScript::none(), script], usize::MAX)
            .unwrap_or_else(|e| panic!("sever at reply {fault_at}: run failed: {e:#}"));
        assert_matches_reference(&got, &want, &format!("state-RPC sever at reply {fault_at}"));
    }
}

// ---------------------------------------------------------------------------
// Wire protocol v5: elastic membership — kill-and-replace chaos, spare
// exhaustion, staged rebalancing, and the down-pinned refusal. Every
// test here is prefixed `elastic_` (the dedicated CI leg filters on it;
// the base legs skip it).
// ---------------------------------------------------------------------------

/// Run an elastic in-proc fleet (2 seats + `spares` warm spares, sync
/// snapshots every 3 steps) over the chaos gradient stream, killing
/// workers at the scripted `(step, seat)` points; return final params,
/// refresh count, and the fleet event counters.
fn elastic_chaos_run(
    overlap: bool,
    spares: usize,
    kills: &[(usize, usize)],
) -> anyhow::Result<(Vec<Matrix>, usize, FleetStats)> {
    let transports: Vec<Arc<FaultInjectingTransport>> = (0..2 + spares)
        .map(|_| {
            FaultInjectingTransport::with_config(
                FaultScript::none(),
                usize::MAX,
                Some(Duration::from_secs(2)),
            )
        })
        .collect();
    let mut eng = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
        .spares(spares)
        .failover_budget(3)
        .build(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(overlap))?;
    let control = eng.fleet_control().expect("shard engines expose fleet control");
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for step in 0..CHAOS_STEPS {
        for &(at, seat) in kills {
            if at == step {
                control.kill_worker(seat)?;
            }
        }
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads)?;
    }
    Ok((params, eng.refreshes(), control.stats()))
}

#[test]
fn elastic_kill_and_replace_sweep_matches_local_bitwise() {
    // The acceptance sweep: kill each seat once, at an early and a late
    // point, under both the synchronous and the RefreshAhead-pipelined
    // schedule — the survivor fleet (seat re-seated on a warm spare
    // from the last synced snapshot + bounded journal replay) must
    // reproduce the uninterrupted local run bit for bit, refresh
    // accounting included.
    let want = chaos_reference();
    assert!(want.1 > 0, "test must exercise refreshes");
    for pipelined in [false, true] {
        for seat in 0..2usize {
            for kill_step in [2usize, 5] {
                let what = format!("pipelined={pipelined} kill seat {seat} at step {kill_step}");
                let (params, refreshes, stats) =
                    elastic_chaos_run(pipelined, 2, &[(kill_step, seat)])
                        .unwrap_or_else(|e| panic!("{what}: run failed: {e:#}"));
                assert_matches_reference(&(params, refreshes), &want, &what);
                assert_eq!(stats.migrations, 1, "{what}: one migration");
                assert!(
                    stats.migrated_steps <= 3,
                    "{what}: replay must stay within the failover budget \
                     (replayed {})",
                    stats.migrated_steps
                );
            }
        }
        // Both seats killed in one run: two migrations, same identity.
        let what = format!("pipelined={pipelined} kill both seats");
        let (params, refreshes, stats) = elastic_chaos_run(pipelined, 2, &[(2, 0), (5, 1)])
            .unwrap_or_else(|e| panic!("{what}: run failed: {e:#}"));
        assert_matches_reference(&(params, refreshes), &want, &what);
        assert_eq!(stats.migrations, 2, "{what}: two migrations");
    }
}

#[test]
fn elastic_exhausted_spares_surface_a_named_error() {
    // 1 spare, 2 kills: the first kill migrates onto the spare; the
    // second has nowhere to go (in-proc fleets cannot cold-spawn), so
    // the next step must fail loudly instead of hanging or diverging.
    let err = match elastic_chaos_run(false, 1, &[(2, 0), (5, 0)]) {
        Ok(_) => panic!("a second kill with no spare left must fail the run"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no spare remains"), "error must say the fleet is out of spares: {msg}");
}

#[test]
fn elastic_fleet_refuses_down_pinned_links() {
    // Elastic membership needs the membership frames, which only exist
    // from wire protocol v5 — a fleet whose links are pinned below must
    // refuse at launch, not fail mid-migration.
    let transports: Vec<Arc<FaultInjectingTransport>> =
        (0..3).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect();
    let err = match ExecutorBuilder::in_proc(transports, 4, true).spares(1).build(
        &CHAOS_SHAPES,
        UnitKind::Shampoo,
        overlap_base(),
        chaos_ecfg(false),
    ) {
        Ok(_) => panic!("elastic launch over down-pinned links must fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("wire protocol v5"), "refusal must name the version gap: {msg}");
}

#[test]
fn elastic_non_shard_builders_refuse_membership_knobs() {
    // The builder refuses elastic knobs on executors with no fleet —
    // a spares setting that silently did nothing would be worse than
    // an error.
    let err = match ExecutorBuilder::local().spares(1).build(
        &CHAOS_SHAPES,
        UnitKind::Shampoo,
        overlap_base(),
        chaos_ecfg(false),
    ) {
        Ok(_) => panic!("local + spares must refuse"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("needs a shard fleet"),
        "refusal must point at the sharded builders: {err:#}"
    );
}

#[test]
fn elastic_staged_rebalance_stays_bitwise() {
    // An operator-staged rebalance (skewed weights) applies at the next
    // sync point: blocks migrate between live seats over the same
    // snapshot/restore path, the epoch advances, and the run stays
    // bitwise identical to the uninterrupted local reference.
    let want = chaos_reference();
    let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
        .map(|_| {
            FaultInjectingTransport::with_config(
                FaultScript::none(),
                usize::MAX,
                Some(Duration::from_secs(2)),
            )
        })
        .collect();
    let mut eng = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
        .rebalance(true)
        .failover_budget(3)
        .build(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(false))
        .expect("launch rebalancing fleet");
    let control = eng.fleet_control().expect("fleet control");
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for step in 0..CHAOS_STEPS {
        if step == 1 {
            // Applied at the t=3 sync point, not mid-step.
            control.request_rebalance(vec![3.0, 1.0]);
        }
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads).expect("rebalanced step");
    }
    assert_matches_reference(&(params, eng.refreshes()), &want, "staged rebalance");
    let stats = control.stats();
    assert!(stats.rebalances >= 1, "the staged re-cut must apply: {stats:?}");
    assert!(control.epoch() >= 1, "a re-cut advances the membership epoch");
    assert_eq!(stats.migrations, 0, "no seat died in this run");
}

#[test]
#[allow(deprecated)]
fn builder_engines_match_deprecated_constructors_bitwise() {
    // The builder-equivalence contract: every deprecated constructor
    // and its ExecutorBuilder replacement produce engines that step
    // bit-for-bit identically (the builder is a re-plumbing, never a
    // numeric change).
    let shapes = CHAOS_SHAPES;
    let ecfg = chaos_ecfg(false);
    let mut old_local = PrecondEngine::new(&shapes, UnitKind::Shampoo, overlap_base(), ecfg);
    let mut new_local = local_engine(&shapes, UnitKind::Shampoo, overlap_base(), ecfg);
    let mk_transports = || -> Vec<Arc<FaultInjectingTransport>> {
        (0..2).map(|_| FaultInjectingTransport::new(FaultScript::none())).collect()
    };
    let old_t = mk_transports();
    let mut old_shard = PrecondEngine::with_executor(
        &shapes,
        UnitKind::Shampoo,
        overlap_base(),
        ecfg,
        |blocks, kind, base, threads| {
            Ok(Box::new(ShardExecutor::launch_in_proc(
                blocks,
                kind,
                base,
                threads,
                &old_t,
                PROTO_VERSION,
                true,
            )?))
        },
    )
    .expect("deprecated in-proc launch");
    let mut new_shard = in_proc_engine(
        &shapes,
        UnitKind::Shampoo,
        overlap_base(),
        ecfg,
        &mk_transports(),
        PROTO_VERSION,
        true,
    )
    .expect("builder in-proc launch");
    let mut p = [(); 4].map(|_| {
        shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect::<Vec<Matrix>>()
    });
    let mut rng = Pcg64::new(427);
    for step in 0..CHAOS_STEPS {
        let grads = random_grads(&shapes, &mut rng);
        old_local.step(&mut p[0], &grads);
        new_local.step(&mut p[1], &grads);
        old_shard.try_step(&mut p[2], &grads).expect("deprecated sharded step");
        new_shard.try_step(&mut p[3], &grads).expect("builder sharded step");
        for which in 1..4 {
            for (i, (a, b)) in p[0].iter().zip(&p[which]).enumerate() {
                assert_eq!(
                    a.max_diff(b),
                    0.0,
                    "engine {which}: tensor {i} diverged from the deprecated local \
                     reference at step {step}"
                );
            }
        }
    }
    assert_eq!(old_local.refreshes(), new_local.refreshes());
    assert_eq!(old_local.refreshes(), old_shard.refreshes());
    assert_eq!(old_local.refreshes(), new_shard.refreshes());
}

#[test]
fn shards_are_capped_at_block_count() {
    // More shards than blocks must not spawn idle workers.
    let shapes = [(4usize, 4usize)];
    let blocks = partition(&shapes, 4); // a single 4x4 block
    let exec = ShardExecutor::launch_with(
        &mk_launch(3, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base_cfg(),
        1,
        &MembershipConfig::default(),
    )
    .expect("launch executor");
    assert_eq!(exec.shards(), 1);
}

// ---------------------------------------------------------------------------
// Wire protocol v7: EKFAC inter-refresh corrections across the fleet —
// worker-local corrector mutations, typed corrector payloads over
// StateSnap/StateRestore, and the pre-v7 refusal.
// ---------------------------------------------------------------------------

#[test]
fn ekfac_sharded_matches_local_bitwise() {
    // 2- and 4-shard fleets with the corrector live, exact-Kronecker
    // and FD-sketched: per-step corrector mutations are worker-local
    // and deterministic, so shard count must never change the numbers —
    // and refresh accounting must survive the wire too.
    let shapes = [(10usize, 7), (6, 6), (9, 1)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    for (kind, shards, seed) in [
        (UnitKind::Shampoo, 2usize, 440u64),
        (UnitKind::Sketched { rank: 3 }, 2, 441),
        (UnitKind::Shampoo, 4, 442),
    ] {
        let ecfg = EngineConfig {
            threads: 2,
            block_size: 4,
            refresh_interval: 4,
            stagger: true,
            ekfac: true,
            ..Default::default()
        };
        let mut launch = mk_launch(shards, ShardTransport::Tcp);
        launch.compress = true;
        let mut local = local_engine(&shapes, kind, base.clone(), ecfg);
        let mut sharded = sharded_engine(&shapes, kind, base.clone(), ecfg, &launch)
            .expect("launch ekfac sharded engine");
        let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut p2 = p1.clone();
        let mut rng = Pcg64::new(seed);
        for step in 0..12 {
            let grads = random_grads(&shapes, &mut rng);
            local.step(&mut p1, &grads);
            sharded.try_step(&mut p2, &grads).expect("ekfac sharded step");
            for (a, b) in p1.iter().zip(&p2) {
                assert_eq!(
                    a.max_diff(b),
                    0.0,
                    "ekfac {shards}-shard run diverged from local at step {step}"
                );
            }
        }
        assert_eq!(local.refreshes(), sharded.refreshes());
    }
}

#[test]
fn ekfac_state_snapshot_restores_through_fresh_fleet_bitwise() {
    // Corrector diagonals and escaped-mass tails ride the v7 typed
    // state payloads: snapshot a stepped ekfac fleet over StateSnap,
    // kill it, restore a freshly launched fleet over StateRestore, and
    // continue — lockstep with the never-interrupted local reference.
    let shapes = [(9usize, 6), (5, 4)];
    let kind = UnitKind::Sketched { rank: 3 };
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        ekfac: true,
        ..Default::default()
    };
    let mut launch = mk_launch(2, ShardTransport::Tcp);
    launch.compress = true;
    let mut local = local_engine(&shapes, kind, base.clone(), ecfg);
    let mut sharded = sharded_engine(&shapes, kind, base.clone(), ecfg, &launch)
        .expect("launch ekfac sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(443);
    for step in 0..5 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("ekfac sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "ekfac sharded run diverged at step {step}");
        }
    }
    let entries = sharded
        .state_payloads()
        .expect("StateSnap RPC")
        .expect("v7 engines expose typed block state");
    drop(sharded); // the worker fleet dies with its driver
    let mut resumed = sharded_engine(&shapes, kind, base.clone(), ecfg, &launch)
        .expect("relaunch ekfac sharded engine");
    resumed.restore_payloads(5, entries).expect("restore corrector state over StateRestore");
    let mut p3 = p2;
    for step in 5..10 {
        let grads = random_grads(&shapes, &mut rng);
        local.step(&mut p1, &grads);
        resumed.try_step(&mut p3, &grads).expect("resumed ekfac sharded step");
        for (a, b) in p1.iter().zip(&p3) {
            assert_eq!(a.max_diff(b), 0.0, "resumed ekfac run diverged at step {step}");
        }
    }
}

#[test]
fn ekfac_fleet_refuses_pre_v7_workers() {
    // The corrector cannot ship over pre-v7 links (no InitMsg field, no
    // corrector payloads), so assembling an ekfac fleet with any worker
    // pinned below v7 must be a named construction error — silently
    // dropping the correction would change the numbers mid-run.
    let shapes = [(6usize, 6)];
    let base = ShampooConfig { ekfac: true, ..base_cfg() };
    let ecfg = EngineConfig {
        threads: 1,
        block_size: 4,
        refresh_interval: 3,
        stagger: true,
        ekfac: true,
        ..Default::default()
    };
    let mut launch = mk_launch(2, ShardTransport::Tcp);
    launch.proto = 6;
    let err = match sharded_engine(&shapes, UnitKind::Shampoo, base, ecfg, &launch) {
        Ok(_) => panic!("an ekfac fleet over v6 links must refuse to assemble"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("v7"), "refusal must name the protocol floor: {err}");
    assert!(err.contains("ekfac"), "refusal must name the knob: {err}");
}

// ---------------------------------------------------------------------------
// Wire protocol v6: the durable driver — write-ahead journal crash-resume
// and heartbeat supervision of hung workers. Every test here is prefixed
// `driver_` (the dedicated CI leg filters on it; the base legs skip it).
// ---------------------------------------------------------------------------

fn wal_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sketchy_driver_wal_{tag}_{}.skjl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// Elastic 2-seat in-proc fleet journaling to `path` (no spares: the
/// durable journal alone makes the membership elastic). `ekfac` turns
/// the inter-refresh corrector on fleet-wide.
fn journaled_in_proc_engine(
    overlap: bool,
    ekfac: bool,
    path: &str,
) -> anyhow::Result<PrecondEngine> {
    let transports: Vec<Arc<FaultInjectingTransport>> = (0..2)
        .map(|_| {
            FaultInjectingTransport::with_config(
                FaultScript::none(),
                usize::MAX,
                Some(Duration::from_secs(2)),
            )
        })
        .collect();
    ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
        .membership(MembershipConfig {
            journal: Some(path.to_string()),
            failover_budget: 3,
            ..Default::default()
        })
        .build(
            &CHAOS_SHAPES,
            UnitKind::Shampoo,
            ShampooConfig { ekfac, ..overlap_base() },
            EngineConfig { ekfac, ..chaos_ecfg(overlap) },
        )
}

/// The chaos gradient stream as a precomputed list, so a resumed run
/// can pick it up mid-stream (the training loop's data source survives
/// the crash; the journal only has to cover the optimizer).
fn chaos_stream() -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::new(423);
    (0..CHAOS_STEPS).map(|_| random_grads(&CHAOS_SHAPES, &mut rng)).collect()
}

/// Kill the driver after `crash_at` steps and resume it from the
/// write-ahead journal. Phase 1 journals to `path` and is dropped —
/// the WAL is appended + fsynced *before* each step reaches the fleet,
/// so the file on disk is exactly what a `kill -9` at any later point
/// within the step leaves behind. Phase 2 relaunches via `mk_engine`
/// (handed the journaled seat addresses), restores the synced
/// snapshot, replays the journaled steps, and finishes the run. A
/// local twin is pushed through the identical restore/replay sequence:
/// the fleet must match it bitwise per step and on the final refresh
/// count (the accounting survives both the wire and the crash).
fn driver_crash_resume_run(
    crash_at: usize,
    ekfac: bool,
    path: &str,
    mk_engine: &dyn Fn(Option<Vec<String>>) -> anyhow::Result<PrecondEngine>,
) -> anyhow::Result<(Vec<Matrix>, Vec<String>)> {
    let stream = chaos_stream();
    let _ = std::fs::remove_file(path);
    {
        let mut eng = mk_engine(None)?;
        let mut params: Vec<Matrix> =
            CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        for grads in &stream[..crash_at] {
            eng.try_step(&mut params, grads)?;
        }
        // Dropped here: the doomed driver dies. (Process workers die
        // with it — resume exercises the spawn-fresh fallback.)
    }
    let jc = load_journal(path)
        .map_err(|e| anyhow::anyhow!("load the crashed driver's journal: {e:#}"))?;
    anyhow::ensure!(!jc.torn, "a journal closed between appends must not read as torn");
    anyhow::ensure!(
        jc.sync_t as usize + jc.steps.len() == crash_at,
        "journal must cover every applied step: sync {} + {} replay != {crash_at}",
        jc.sync_t,
        jc.steps.len()
    );
    anyhow::ensure!(
        jc.steps.len() as u64 <= 3,
        "replay section exceeds the failover budget: {} steps",
        jc.steps.len()
    );
    let mut eng = mk_engine(Some(jc.addrs.clone()))?;
    let mut twin = local_engine(
        &CHAOS_SHAPES,
        UnitKind::Shampoo,
        ShampooConfig { ekfac, ..overlap_base() },
        EngineConfig { ekfac, ..chaos_ecfg(false) },
    );
    let mut params = jc.params.clone();
    let mut twin_params = jc.params.clone();
    match jc.snaps.clone() {
        Some(snaps) => {
            eng.restore_payloads(jc.sync_t as usize, snaps.clone())?;
            twin.restore_payloads(jc.sync_t as usize, snaps)?;
        }
        None => anyhow::ensure!(jc.sync_t == 0, "a nonzero sync point must carry a snapshot"),
    }
    let replay = jc.steps.iter().map(|rs| (rs.lr, &rs.grads));
    let tail = stream[crash_at..].iter().map(|g| (overlap_base().lr, g));
    for (step, (lr, grads)) in replay.chain(tail).enumerate() {
        eng.set_lr(lr);
        twin.set_lr(lr);
        eng.try_step(&mut params, grads)?;
        twin.step(&mut twin_params, grads);
        for (i, (a, b)) in twin_params.iter().zip(&params).enumerate() {
            anyhow::ensure!(
                a.max_diff(b) == 0.0,
                "resumed fleet diverged from the resumed local twin on tensor {i}, \
                 {step} steps after the restore"
            );
        }
    }
    anyhow::ensure!(
        eng.refreshes() == twin.refreshes(),
        "refresh accounting diverged across the crash: fleet {} vs local {}",
        eng.refreshes(),
        twin.refreshes()
    );
    let _ = std::fs::remove_file(path);
    Ok((params, jc.addrs))
}

#[test]
fn driver_crash_resume_from_journal_matches_reference_bitwise() {
    // The acceptance sweep: kill the driver after *every* scripted step
    // in turn, under both the synchronous and the RefreshAhead-
    // pipelined schedule, and relaunch from the write-ahead journal.
    // The resumed run must land bitwise on the uninterrupted local
    // reference, refresh accounting included — the crash is invisible
    // in the final parameters.
    let want = chaos_reference();
    assert!(want.1 > 0, "test must exercise refreshes");
    for pipelined in [false, true] {
        for crash_at in 1..=CHAOS_STEPS {
            let what = format!("pipelined={pipelined} crash after step {crash_at}");
            let path = wal_path(&format!("inproc_{}_{crash_at}", pipelined as u8));
            let mk = |_: Option<Vec<String>>| journaled_in_proc_engine(pipelined, false, &path);
            let (params, addrs) = driver_crash_resume_run(crash_at, false, &path, &mk)
                .unwrap_or_else(|e| panic!("{what}: {e:#}"));
            for (i, (a, b)) in want.0.iter().zip(&params).enumerate() {
                assert_eq!(a.max_diff(b), 0.0, "{what}: tensor {i} diverged from reference");
            }
            assert!(
                addrs.iter().all(String::is_empty),
                "{what}: in-proc seats must journal as non-re-adoptable: {addrs:?}"
            );
        }
    }
}

#[test]
fn driver_crash_resume_with_ekfac_matches_reference_bitwise() {
    // Corrector state crosses the crash: the journal's sync-point
    // snapshot carries the v7 corrector payloads and the replay
    // re-runs the per-step corrector mutations deterministically, so a
    // driver killed mid-run with --ekfac on (sync and RefreshAhead)
    // must land bitwise on the uninterrupted ekfac reference.
    // (`chaos_reference` is the non-ekfac baseline, so the reference
    // is computed inline here with the corrector live.)
    let want = {
        let mut eng = local_engine(
            &CHAOS_SHAPES,
            UnitKind::Shampoo,
            ShampooConfig { ekfac: true, ..overlap_base() },
            EngineConfig { ekfac: true, ..chaos_ecfg(false) },
        );
        let mut params: Vec<Matrix> =
            CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        let mut rng = Pcg64::new(423);
        for _ in 0..CHAOS_STEPS {
            let grads = random_grads(&CHAOS_SHAPES, &mut rng);
            eng.step(&mut params, &grads);
        }
        (params, eng.refreshes())
    };
    assert!(want.1 > 0, "test must exercise refreshes");
    // The corrected run must actually differ from the frozen-scale run
    // — otherwise this test would pass with the corrector silently
    // dropped across the crash.
    let frozen = chaos_reference();
    assert!(
        want.0.iter().zip(&frozen.0).any(|(a, b)| a.max_diff(b) != 0.0),
        "ekfac reference matches the frozen-scale reference — corrector inert"
    );
    for (pipelined, crash_at) in [(false, 4usize), (true, 5)] {
        let what = format!("ekfac pipelined={pipelined} crash after step {crash_at}");
        let path = wal_path(&format!("ekfac_{}_{crash_at}", pipelined as u8));
        let mk = |_: Option<Vec<String>>| journaled_in_proc_engine(pipelined, true, &path);
        let (params, _) = driver_crash_resume_run(crash_at, true, &path, &mk)
            .unwrap_or_else(|e| panic!("{what}: {e:#}"));
        for (i, (a, b)) in want.0.iter().zip(&params).enumerate() {
            assert_eq!(a.max_diff(b), 0.0, "{what}: tensor {i} diverged from ekfac reference");
        }
    }
}

#[test]
fn driver_crash_process_fleet_resumes_from_journal_bitwise() {
    // Same contract through real worker processes. Dropping the doomed
    // driver shuts its workers down with it, so the journaled tcp
    // addresses point at dead workers — the relaunch walks the
    // re-adopt-or-spawn-fresh fallback and must still land bitwise on
    // the reference (every seat is re-Init'd from scratch, so adopted
    // and fresh fleets are identical by construction).
    let want = chaos_reference();
    for (pipelined, crash_at) in [(false, 4usize), (true, 5)] {
        let what = format!("pipelined={pipelined} crash after step {crash_at}");
        let path = wal_path(&format!("proc_{}_{crash_at}", pipelined as u8));
        let mut launch = mk_launch(2, ShardTransport::Tcp);
        launch.compress = true;
        let mk = |resume: Option<Vec<String>>| {
            ExecutorBuilder::sharded(launch.clone())
                .membership(MembershipConfig {
                    journal: Some(path.clone()),
                    failover_budget: 3,
                    resume_addrs: resume,
                    ..Default::default()
                })
                .build(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(pipelined))
        };
        let (params, addrs) =
            driver_crash_resume_run(crash_at, false, &path, &mk)
                .unwrap_or_else(|e| panic!("{what}: {e:#}"));
        for (i, (a, b)) in want.0.iter().zip(&params).enumerate() {
            assert_eq!(a.max_diff(b), 0.0, "{what}: tensor {i} diverged from reference");
        }
        assert!(
            addrs.iter().all(|a| a.starts_with("tcp ")),
            "{what}: process seats must journal dialable addresses: {addrs:?}"
        );
    }
}

#[test]
fn driver_torn_journal_tail_falls_back_to_the_previous_sync_point() {
    // A crash *during* an append leaves a torn record. Resume recovers
    // the sync point plus the surviving replay prefix; the lost tail
    // steps are re-fed from the data stream (their gradients are a pure
    // function of the stream position), landing back on the reference
    // bitwise. Crash after step 5 with budget 3: sync at t=3, records
    // for t=4 and t=5 — the cut lands inside t=5's record.
    let want = chaos_reference();
    let stream = chaos_stream();
    let path = wal_path("torn");
    let _ = std::fs::remove_file(&path);
    {
        let mut eng = journaled_in_proc_engine(false, false, &path).expect("launch journaled fleet");
        let mut params: Vec<Matrix> =
            CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
        for grads in &stream[..5] {
            eng.try_step(&mut params, grads).expect("journaled step");
        }
    }
    let full = std::fs::read(&path).expect("read journal");
    std::fs::write(&path, &full[..full.len() - 9]).expect("tear the journal tail");
    let jc = load_journal(&path).expect("torn-tail recovery");
    assert!(jc.torn, "the cut record must be reported");
    assert_eq!(jc.sync_t, 3, "recovery falls back to the t=3 sync point");
    assert_eq!(jc.steps.len(), 1, "only the complete t=4 record survives");
    assert_eq!(jc.steps[0].t, 4);
    let mut eng = journaled_in_proc_engine(false, false, &path).expect("relaunch fleet");
    let mut twin = local_engine(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(false));
    let mut params = jc.params.clone();
    let mut twin_params = jc.params.clone();
    let snaps = jc.snaps.clone().expect("synced snapshot");
    eng.restore_payloads(jc.sync_t as usize, snaps.clone()).expect("restore fleet from journal");
    twin.restore_payloads(jc.sync_t as usize, snaps).expect("restore local twin");
    let resumed_from = jc.sync_t as usize + jc.steps.len();
    for rs in &jc.steps {
        eng.set_lr(rs.lr);
        twin.set_lr(rs.lr);
        eng.try_step(&mut params, &rs.grads).expect("replay journaled step");
        twin.step(&mut twin_params, &rs.grads);
    }
    for grads in &stream[resumed_from..] {
        eng.try_step(&mut params, grads).expect("post-resume step");
        twin.step(&mut twin_params, grads);
    }
    for (i, (a, b)) in want.0.iter().zip(&params).enumerate() {
        assert_eq!(a.max_diff(b), 0.0, "torn tail: tensor {i} diverged from reference");
    }
    for (i, (a, b)) in twin_params.iter().zip(&params).enumerate() {
        assert_eq!(a.max_diff(b), 0.0, "torn tail: tensor {i} diverged from the local twin");
    }
    assert_eq!(eng.refreshes(), twin.refreshes(), "torn tail: refresh accounting diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn driver_hung_worker_is_replaced_at_the_deadline_not_the_reply_timeout() {
    // A hung worker: seat 0's step-4 reply frame is dropped while the
    // connection stays up, so nothing ever arrives and a plain blocking
    // read would sit out the full 120 s reply timeout. The v6
    // supervisor must instead escalate at the liveness deadline on the
    // injected virtual clock (advanced only by observed silent polls)
    // and migrate the seat onto the warm spare. Seat 0's reply frames:
    // 0 hello, 1 init-ok, 2-4 steps 1-3, 5 the t=3 sync snapshot, 6
    // step 4 — the dropped one.
    let want = chaos_reference();
    let script = FaultScript::none().on_reply(6, FaultAction::DropFrame);
    let transports: Vec<Arc<FaultInjectingTransport>> =
        [script, FaultScript::none(), FaultScript::none()]
            .into_iter()
            .map(|s| {
                FaultInjectingTransport::with_config(s, usize::MAX, Some(Duration::from_secs(2)))
            })
            .collect();
    let timeouts = LinkTimeouts {
        heartbeat: Duration::from_millis(50),
        deadline: Duration::from_millis(1000),
        // The reply bound keeps its 120 s default: reaching it would
        // blow the wall-clock assertion below.
        ..LinkTimeouts::default()
    };
    let mut eng = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
        .membership(MembershipConfig {
            spares: 1,
            failover_budget: 3,
            timeouts,
            ..Default::default()
        })
        .clock(Arc::new(VirtualClock::new()))
        .build(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(false))
        .expect("launch supervised fleet");
    let control = eng.fleet_control().expect("fleet control");
    let started = std::time::Instant::now();
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for _ in 0..CHAOS_STEPS {
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads).expect("supervised step");
    }
    let elapsed = started.elapsed();
    assert_matches_reference(&(params, eng.refreshes()), &want, "hung-worker run");
    let stats = control.stats();
    assert_eq!(
        stats.migrations, 1,
        "the hung seat must be killed and replaced via the heartbeat deadline (an \
         unsupervised link would instead recover by reconnect-replay, migrating nothing): \
         {stats:?}"
    );
    assert!(
        stats.migrated_steps <= 3,
        "replay must stay within the failover budget: {stats:?}"
    );
    assert!(
        elapsed < Duration::from_secs(60),
        "detection must ride the deadline, not the blocking reply timeout (took {elapsed:?})"
    );
}

#[test]
fn driver_idle_probe_pings_keep_the_fleet_bitwise() {
    // The quiet side of supervision: advancing the virtual clock past
    // the heartbeat interval between steps makes every seat ping-due,
    // so the driver probes the fleet with Ping/Pong round-trips before
    // each step commits to the wire. Probes are pure control traffic —
    // the run must stay bitwise identical with zero migrations.
    let want = chaos_reference();
    let transports: Vec<Arc<FaultInjectingTransport>> = (0..3)
        .map(|_| {
            FaultInjectingTransport::with_config(
                FaultScript::none(),
                usize::MAX,
                Some(Duration::from_secs(2)),
            )
        })
        .collect();
    let clock = Arc::new(VirtualClock::new());
    let mut eng = ExecutorBuilder::in_proc(transports, PROTO_VERSION, true)
        .spares(1)
        .failover_budget(3)
        .clock(clock.clone())
        .build(&CHAOS_SHAPES, UnitKind::Shampoo, overlap_base(), chaos_ecfg(false))
        .expect("launch supervised fleet");
    let control = eng.fleet_control().expect("fleet control");
    let mut params: Vec<Matrix> = CHAOS_SHAPES.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut rng = Pcg64::new(423);
    for _ in 0..CHAOS_STEPS {
        // Default heartbeat is 500 ms; 600 ms of virtual idleness makes
        // both seats probe-due (but stays far from the 10 s deadline).
        clock.advance(Duration::from_millis(600));
        let grads = random_grads(&CHAOS_SHAPES, &mut rng);
        eng.try_step(&mut params, &grads).expect("probed step");
    }
    assert_matches_reference(&(params, eng.refreshes()), &want, "idle-probe run");
    let stats = control.stats();
    assert_eq!(stats.migrations, 0, "a healthy pinged fleet migrates nothing: {stats:?}");
}
