//! Cross-process shard engine: bitwise determinism and failure handling.
//!
//! The contract under test: partitioning preconditioner blocks across
//! `sketchy shard-worker` processes is an *execution* decision, never a
//! numeric one — a 2-shard or 4-shard run must produce parameters
//! **bitwise identical** to the in-process engine, for every unit kind
//! and transport. These tests spawn real worker processes from the
//! built `sketchy` binary (`CARGO_BIN_EXE_sketchy`); the CI
//! `shard-smoke` job runs them in release mode.

use sketchy::coordinator::shard::{ShardExecutor, ShardLaunch, ShardTransport};
use sketchy::optim::precond::StepCtx;
use sketchy::optim::{
    partition, Adam, BlockExecutor, EngineConfig, GraftType, LocalExecutor, Optimizer,
    PrecondEngine, ShampooConfig, UnitKind,
};
use sketchy::tensor::Matrix;
use sketchy::util::rng::Pcg64;
use std::path::PathBuf;

fn sketchy_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_sketchy"))
}

fn mk_launch(shards: usize, transport: ShardTransport) -> ShardLaunch {
    ShardLaunch { program: sketchy_bin(), shards, transport }
}

fn base_cfg() -> ShampooConfig {
    ShampooConfig {
        lr: 0.05,
        start_preconditioning_step: 2,
        graft: GraftType::Rmsprop,
        clip: 5.0,
        weight_decay: 1e-3,
        ..Default::default()
    }
}

fn random_grads(shapes: &[(usize, usize)], rng: &mut Pcg64) -> Vec<Matrix> {
    shapes.iter().map(|&(m, n)| Matrix::randn(m, n, rng)).collect()
}

/// Step the in-process engine and an N-shard engine on one gradient
/// stream; assert bitwise-equal parameters after every step and equal
/// refresh accounting at the end.
fn assert_sharded_matches_local(
    shapes: &[(usize, usize)],
    kind: UnitKind,
    block_size: usize,
    shards: usize,
    transport: ShardTransport,
    steps: usize,
    seed: u64,
) {
    let ecfg = EngineConfig {
        threads: 2,
        block_size,
        refresh_interval: 3,
        stagger: true,
        ..Default::default()
    };
    let mut local = PrecondEngine::new(shapes, kind, base_cfg(), ecfg);
    let mut sharded =
        PrecondEngine::sharded(shapes, kind, base_cfg(), ecfg, &mk_launch(shards, transport))
            .expect("launch sharded engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(seed);
    for step in 0..steps {
        let grads = random_grads(shapes, &mut rng);
        local.step(&mut p1, &grads);
        sharded.try_step(&mut p2, &grads).expect("sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(
                a.max_diff(b),
                0.0,
                "{shards}-shard run diverged from in-process engine at step {step}"
            );
        }
    }
    assert_eq!(
        local.refreshes(),
        sharded.refreshes(),
        "refresh accounting must survive the wire"
    );
}

#[test]
fn two_shard_tcp_matches_single_process_bitwise() {
    let shapes = [(10, 7), (6, 6), (9, 1)];
    assert_sharded_matches_local(&shapes, UnitKind::Shampoo, 4, 2, ShardTransport::Tcp, 12, 410);
}

#[test]
fn four_shard_tcp_matches_single_process_bitwise() {
    let shapes = [(12, 10), (8, 3)];
    assert_sharded_matches_local(
        &shapes,
        UnitKind::Sketched { rank: 3 },
        5,
        4,
        ShardTransport::Tcp,
        12,
        411,
    );
}

#[cfg(unix)]
#[test]
fn two_shard_unix_socket_matches_single_process_bitwise() {
    let shapes = [(8, 8), (5, 4)];
    assert_sharded_matches_local(&shapes, UnitKind::Shampoo, 4, 2, ShardTransport::Unix, 8, 412);
}

#[test]
fn sharded_engine_adam_equals_fused_adam() {
    // The Adam normalization path (grafting / driver momentum stripped)
    // must survive the wire: a 2-shard engine-adam reproduces the fused
    // Adam bitwise across an arbitrary block partition.
    let shapes = [(5, 4), (3, 3)];
    let mut fused = Adam::new(&shapes, 0.05);
    fused.weight_decay = 0.01;
    fused.clip = 1.0;
    let base = ShampooConfig {
        lr: 0.05,
        beta2: 0.999,
        weight_decay: 0.01,
        clip: 1.0,
        beta1: 0.9,
        start_preconditioning_step: 7,
        stat_interval: 2,
        precond_interval: 3,
        graft: GraftType::RmspropNormalized,
        ..Default::default()
    };
    let ecfg = EngineConfig {
        threads: 2,
        block_size: 2,
        refresh_interval: 1,
        stagger: false,
        ..Default::default()
    };
    let mut engine = PrecondEngine::sharded(
        &shapes,
        UnitKind::Adam,
        base,
        ecfg,
        &mk_launch(2, ShardTransport::Tcp),
    )
    .expect("launch sharded adam engine");
    let mut p1: Vec<Matrix> = shapes.iter().map(|&(m, n)| Matrix::zeros(m, n)).collect();
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(413);
    for step in 0..15 {
        let grads = random_grads(&shapes, &mut rng);
        fused.step(&mut p1, &grads);
        engine.try_step(&mut p2, &grads).expect("sharded step");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.max_diff(b), 0.0, "sharded engine-adam diverged at step {step}");
        }
    }
}

/// Deterministic per-block contexts for driving executors directly.
fn mk_ctxs(n_blocks: usize, t: usize) -> Vec<StepCtx> {
    (0..n_blocks)
        .map(|i| StepCtx {
            t,
            scale: 1.0,
            preconditioning: t >= 2,
            refresh_due: (t + i % 3) % 3 == 0,
            lr: 0.05,
            beta1: 0.9,
            weight_decay: 1e-3,
            stat_due: true,
            graft: GraftType::Rmsprop,
        })
        .collect()
}

#[test]
fn driver_reconnects_after_dropped_connections() {
    // Sever every driver-side connection mid-run: the workers keep
    // their block state across connections, so the run continues and
    // stays bitwise identical to the local executor.
    let shapes = [(6usize, 6usize)];
    let blocks = partition(&shapes, 3);
    let base = base_cfg();
    let mut local = LocalExecutor::new(&blocks, UnitKind::Shampoo, &base, 1);
    let mut exec = ShardExecutor::launch(
        &mk_launch(2, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base,
        1,
    )
    .expect("launch executor");
    let mut p1 = vec![Matrix::zeros(6, 6)];
    let mut p2 = p1.clone();
    let mut rng = Pcg64::new(414);
    for t in 1..=6usize {
        let grads = vec![Matrix::randn(6, 6, &mut rng)];
        let ctxs = mk_ctxs(blocks.len(), t);
        local.step_blocks(&blocks, &mut p1, &grads, &ctxs).unwrap();
        exec.step_blocks(&blocks, &mut p2, &grads, &ctxs).expect("sharded step");
        assert_eq!(p1[0].max_diff(&p2[0]), 0.0, "diverged at step {t}");
        if t == 3 {
            exec.drop_connections();
        }
    }
}

#[test]
fn dead_worker_is_surfaced_with_its_shard_id() {
    let shapes = [(6usize, 6usize)];
    let blocks = partition(&shapes, 3);
    let base = base_cfg();
    let mut exec = ShardExecutor::launch(
        &mk_launch(2, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base,
        1,
    )
    .expect("launch executor");
    assert_eq!(exec.shards(), 2);
    let mut params = vec![Matrix::zeros(6, 6)];
    let mut rng = Pcg64::new(415);
    let grads = vec![Matrix::randn(6, 6, &mut rng)];
    exec.step_blocks(&blocks, &mut params, &grads, &mk_ctxs(blocks.len(), 1))
        .expect("first step");
    exec.kill_worker(1).expect("fault injection");
    let err = exec
        .step_blocks(&blocks, &mut params, &grads, &mk_ctxs(blocks.len(), 2))
        .expect_err("step through a dead worker must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("shard 1"), "error must name the dead shard: {msg}");
}

#[test]
fn spawn_failure_is_surfaced() {
    let shapes = [(4usize, 4usize)];
    let blocks = partition(&shapes, 4);
    let bogus = ShardLaunch {
        program: PathBuf::from("/definitely/not/a/real/binary"),
        shards: 1,
        transport: ShardTransport::Tcp,
    };
    let err = match ShardExecutor::launch(&bogus, &blocks, UnitKind::Shampoo, &base_cfg(), 1) {
        Ok(_) => panic!("bogus worker binary must fail the launch"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("shard 0"), "got: {err:#}");
}

#[test]
fn shards_are_capped_at_block_count() {
    // More shards than blocks must not spawn idle workers.
    let shapes = [(4usize, 4usize)];
    let blocks = partition(&shapes, 4); // a single 4x4 block
    let exec = ShardExecutor::launch(
        &mk_launch(3, ShardTransport::Tcp),
        &blocks,
        UnitKind::Shampoo,
        &base_cfg(),
        1,
    )
    .expect("launch executor");
    assert_eq!(exec.shards(), 1);
}
