//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no registry access, so the workspace vendors
//! the slice of anyhow's surface this repository actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait. Errors are flattened to their display
//! chain at conversion time — no backtraces, no downcasting — which is all
//! the callers here need (error strings surface in CLI output and tests).

use std::fmt;

/// String-backed error type standing in for `anyhow::Error`.
///
/// Deliberately does **not** implement `std::error::Error`, exactly like
/// the real crate, so the blanket `From<E: std::error::Error>` conversion
/// below stays coherent with `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    fn wrap_context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Flatten the source chain into one display string.
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn conversion_and_context() {
        let err = io_fail().unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("reading config: "), "got: {msg}");
    }

    #[test]
    fn macros() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert_eq!(inner(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(inner(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
        assert_eq!(format!("{e:#}"), "code 7");
    }

    #[test]
    fn bare_ensure_reports_condition() {
        fn inner() -> Result<()> {
            let n = 1usize;
            ensure!(n == 2);
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("n == 2"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }
}
