//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links `xla_extension` (a multi-GB native bundle) and is
//! not available in the hermetic build environment, so this stub keeps the
//! workspace compiling and the host-side data plumbing fully testable:
//!
//! - [`Literal`] is **functional**: construction, reshape, typed readback
//!   and tuple decomposition behave like the real host literals, so all
//!   literal round-trip code and its tests run for real.
//! - The device plane ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`]) is **gated**: calls return a
//!   descriptive [`Error`]. Training/experiment code already treats a
//!   missing `artifacts/manifest.json` as "skip", so nothing reaches the
//!   gate in CI; swapping this crate for the real bindings re-enables
//!   execution without touching `sketchy` itself.

use std::fmt;

/// Stub error type (the real crate's `Error` is richer; callers only
/// propagate it into `anyhow`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn backend_unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the real PJRT backend; this build vendors the offline `xla` stub \
         (vendor/xla). Point the `xla` dependency at the real xla-rs bindings to execute \
         compiled artifacts."
    ))
}

/// Element types the repository's literals use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    I32,
    I64,
    F32,
    F64,
    Tuple,
}

/// Payload storage for [`Literal`].
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(data: &Data) -> Option<&[Self]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
    fn wrap(v: Vec<Self>) -> Data {
        Data::F64(v)
    }
    fn unwrap(data: &Data) -> Option<&[Self]> {
        match data {
            Data::F64(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::I32;
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(data: &Data) -> Option<&[Self]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side tensor literal (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { shape: vec![values.len() as i64], data: T::wrap(values.to_vec()) }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { shape: vec![elements.len() as i64], data: Data::Tuple(elements) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!("reshape {dims:?} has {want} elements, literal has {have}")));
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    /// Number of elements (tuple: number of members).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Dimensions.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Element type.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::F64(_) => ElementType::F64,
            Data::I32(_) => ElementType::I32,
            Data::Tuple(_) => ElementType::Tuple,
        })
    }

    /// Typed readback of the flat payload.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).map(|s| s.to_vec()).ok_or_else(|| {
            let have = self.data_ty();
            Error(format!("to_vec type mismatch: literal is {have:?}, asked for {:?}", T::TY))
        })
    }

    /// Decompose a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            other => Err(Error(format!("to_tuple on non-tuple literal {other:?}"))),
        }
    }

    fn data_ty(&self) -> ElementType {
        self.ty().expect("infallible in the stub")
    }
}

/// Parsed-from-text HLO module handle.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact from disk. The stub validates only that
    /// the file is readable; compilation is where the gate sits.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper around a module proto.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. Construction succeeds so manifest loading and
/// artifact listing work; compilation is gated.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// CPU client (always constructible in the stub).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    /// Gated: the stub cannot lower HLO to executables.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(backend_unavailable("compiling an HLO artifact"))
    }
}

/// Compiled-executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _priv: (),
}

/// Types accepted as execution arguments.
pub trait ExecuteInput {}

impl ExecuteInput for Literal {}

impl PjRtLoadedExecutable {
    /// Gated: unreachable in practice since `compile` never succeeds.
    pub fn execute<T: ExecuteInput>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(backend_unavailable("executing an artifact"))
    }
}

impl PjRtBuffer {
    /// Gated device-to-host transfer.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(backend_unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.shape(), &[2, 2]);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        assert_eq!(t.ty().unwrap(), ElementType::Tuple);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
    }

    #[test]
    fn device_plane_is_gated() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
